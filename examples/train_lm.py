"""End-to-end LM training driver (reduced config, CPU) with the full
production loop: microbatched AdamW, checkpoint/resume, fault injection.

    PYTHONPATH=src python examples/train_lm.py --arch qwen2-7b --steps 100
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, smoke_config
from repro.data import SyntheticLMData
from repro.models.lm.api import build
from repro.optim import AdamWConfig
from repro.train import make_train_step, train_loop
from repro.train.step import init_train_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--microbatches", type=int, default=2)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    api = build(cfg)
    opt = AdamWConfig(lr=1e-2, weight_decay=0.0)
    state = init_train_state(api, jax.random.key(0), opt)
    step = make_train_step(
        api, opt, microbatches=args.microbatches, lr_schedule=lambda s: jnp.asarray(1e-2)
    )
    data = SyntheticLMData(
        vocab_size=cfg.vocab_size, seq_len=32, global_batch=8, seed=0,
        with_frames=cfg.frontend == "audio", frame_len=cfg.encoder_seq, d_model=cfg.d_model,
    )
    state, hist = train_loop(
        state=state, train_step=step, data=data, steps=args.steps,
        ckpt_dir=args.ckpt, log_every=10,
    )
    print(f"final loss: {hist[-1]['loss']:.4f} (start {hist[0]['loss']:.4f})")


if __name__ == "__main__":
    main()
