"""Serve HGNN graph requests with a cross-request FP cache.

    PYTHONPATH=src python examples/serve_hgnn.py

Twelve concurrent subgraph queries over the synthetic IMDB HetGraph
arrive in an adversarial interleaved order (director-heavy, actor-heavy
and keyword-heavy requests alternating).  Similarity-aware admission
reorders and co-batches them so consecutive requests share
projected-feature blocks; the FIFO baseline thrashes the cache.  Outputs
are bit-identical either way — the cache only removes recomputation.
"""
import argparse
import time

import numpy as np

from repro.core import NABackend
from repro.graphs import synthetic_hetgraph
from repro.serve import HGNNEngine, make_request_mix

CLUSTERS = [
    [("movie", "director", "movie"), ("movie", "director", "movie", "director", "movie")],
    [("movie", "actor", "movie"), ("movie", "actor", "movie", "actor", "movie")],
    [("movie", "keyword", "movie")],
]


def build_engine(graph, admission, cache_bytes):
    return HGNNEngine(
        graph,
        target_type="movie",
        num_slots=2,
        cache_bytes=cache_bytes,
        cache_block_rows=64,
        admission=admission,
        backend=NABackend.BLOCK,  # NABackend.MULTIGRAPH on TPU
        block=8,
        max_edges=8_000,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeats", type=int, default=4)
    args = ap.parse_args()

    graph = synthetic_hetgraph("imdb", scale=0.05, feat_scale=0.02, seed=0)
    out_bytes = 2 * 8 * 4  # heads * hidden * fp32
    table = {t: n * out_bytes for t, n in graph.vertex_counts.items()}
    cache_bytes = table["movie"] + max(table.values()) + 64 * out_bytes

    results = {}
    for admission in ("fifo", "similarity"):
        eng = build_engine(graph, admission, cache_bytes)
        for req in make_request_mix(0, CLUSTERS, repeats=args.repeats):
            eng.submit(req)
        t0 = time.perf_counter()
        finished = eng.run()
        dt = time.perf_counter() - t0
        m = eng.metrics()
        results[admission] = (finished, m)
        print(f"[{admission}] {m['requests_finished']} requests, {m['steps']} steps, "
              f"{dt:.2f}s  hit_rate={m['cache_hit_rate']:.2f} "
              f"fp_rows_computed={m['fp_rows_computed']} "
              f"(naive {m['fp_rows_naive']}, {m['fp_compute_reduction']:.1f}x saved)")
        for req in finished[:3]:
            emb = np.asarray(req.result)
            print(f"  rid={req.rid} admitted@{req.admitted_step} finished@{req.finished_step} "
                  f"beta={np.round(np.asarray(req.beta), 3).tolist()} |emb|={np.linalg.norm(emb):.3f}")

    fifo, sim = results["fifo"][1], results["similarity"][1]
    print(f"\nsimilarity admission computes "
          f"{fifo['fp_rows_computed'] / max(sim['fp_rows_computed'], 1):.1f}x fewer FP rows than FIFO")
    a = {r.rid: np.asarray(r.result) for r in results["fifo"][0]}
    b = {r.rid: np.asarray(r.result) for r in results["similarity"][0]}
    assert all(np.array_equal(a[k], b[k]) for k in a), "admission order changed results!"
    print("outputs bit-identical across admission policies")


if __name__ == "__main__":
    main()
