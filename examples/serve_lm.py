"""Serve a small LM with batched requests: prefill + batched greedy decode.

    PYTHONPATH=src python examples/serve_lm.py --arch llama3.2-3b --steps 16

Uses the reduced (smoke) config of the chosen architecture so it runs on
CPU; the same engine lowers the full configs in the dry-run.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, smoke_config
from repro.models.lm.api import build
from repro.serve.engine import greedy_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=16)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    api = build(cfg)
    params = api.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (args.batch, 8)), jnp.int32)

    t0 = time.time()
    out = greedy_generate(api, params, prompts, steps=args.steps, cache_len=8 + args.steps + 1)
    dt = time.time() - t0
    toks = args.batch * args.steps
    print(f"arch={cfg.name} family={cfg.family}")
    print(f"generated {toks} tokens in {dt:.2f}s ({toks/dt:.1f} tok/s on CPU)")
    for i, row in enumerate(np.asarray(out)):
        print(f"  request {i}: {row.tolist()}")


if __name__ == "__main__":
    main()
