"""Quickstart: the HiHGNN pipeline end to end on synthetic DBLP.

    PYTHONPATH=src python examples/quickstart.py

Builds semantic graphs from metapaths (SGB), orders them by the shortest
Hamilton path over the similarity graph, balances block-row workloads
across lanes, and runs the fused HAN layer — every HiHGNN mechanism in
~60 lines.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    NABackend,
    batch_semantic_graph,
    count_reuse,
    similarity_schedule,
)
from repro.core.multilane import build_multilane_plan, multilane_na
from repro.graphs import build_semantic_graphs, dataset_metapaths, dataset_target, synthetic_hetgraph, synthetic_labels
from repro.models.hgnn import MODELS, prepare_data


def main():
    # 1. Semantic Graph Build (host preprocessing, like the paper)
    g = synthetic_hetgraph("dblp", scale=0.1, feat_scale=0.1, seed=0)
    sgs = build_semantic_graphs(g, dataset_metapaths("dblp"), max_edges=100_000)
    print("semantic graphs:", [(s.name, s.num_edges) for s in sgs])

    # 2. Similarity-aware execution scheduling (shortest Hamilton path)
    order, w = similarity_schedule(sgs, g.vertex_counts)
    print("execution order:", [sgs[i].name for i in order])

    # 3. RAB-style reuse accounting
    c = count_reuse(sgs, g.vertex_counts)
    print(f"FP work saved by dedup: {c.fp_saved:.0%}; theta work saved: {c.theta_saved:.0%}")

    # 4. Workload-aware lane balancing (independency-aware parallelism)
    batches = [batch_semantic_graph(s, block=32) for s in sgs]
    plan = build_multilane_plan(batches, num_lanes=4)
    print("lane loads (edges):", plan.lane_plan.lane_load.astype(int).tolist(),
          f"imbalance={plan.lane_plan.imbalance():.2f}")

    # 5. Fused HAN forward + a few training steps
    target, ncls = dataset_target("dblp")
    labels = synthetic_labels(g, "dblp")
    data = prepare_data(g, [sgs[i] for i in order], target, ncls, labels, block=32)
    model = MODELS["HAN"]
    params = model.init(jax.random.key(0), data)

    from repro.models.hgnn import cross_entropy

    @jax.jit
    def step(p):
        loss, grads = jax.value_and_grad(
            lambda p_: cross_entropy(model.forward(p_, data, backend=NABackend.SEGMENT), data.labels)
        )(p)
        return jax.tree_util.tree_map(lambda a, g_: a - 0.05 * g_, p, grads), loss

    for i in range(10):
        params, loss = step(params)
        if i % 3 == 0:
            print(f"step {i}: loss {float(loss):.4f}")
    print("done — fused HGNN pipeline runs end to end.")


if __name__ == "__main__":
    main()
