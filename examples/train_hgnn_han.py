"""End-to-end driver: train HAN (~100M-param config) for a few hundred
steps on synthetic ACM with checkpoint/resume.

    PYTHONPATH=src python examples/train_hgnn_han.py [--steps 300]

The model is widened (hidden 128 × 8 heads, att_dim 256, full-scale ACM
features) to ~100M parameters, trained full-batch (transductive node
classification, as HAN trains) with the fused pipeline.
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.core import NABackend, similarity_schedule
from repro.graphs import (
    build_semantic_graphs,
    dataset_metapaths,
    dataset_target,
    synthetic_hetgraph,
    synthetic_labels,
)
from repro.models.hgnn import MODELS, cross_entropy, prepare_data
from repro.models.hgnn.han import init_han
from repro.optim import AdamWConfig, apply_updates, init_opt_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--scale", type=float, default=0.5)
    ap.add_argument("--ckpt", default="artifacts/han_ckpt")
    args = ap.parse_args()

    g = synthetic_hetgraph("acm", scale=args.scale, feat_scale=1.0, seed=0)
    target, ncls = dataset_target("acm")
    labels = synthetic_labels(g, "acm")
    sgs = build_semantic_graphs(g, dataset_metapaths("acm"), max_edges=400_000)
    order, _ = similarity_schedule(sgs, g.vertex_counts)
    data = prepare_data(g, [sgs[i] for i in order], target, ncls, labels, with_blocks=False)

    model = MODELS["HAN"]
    params = init_han(jax.random.key(0), data, hidden=128, heads=8, att_dim=256)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))
    print(f"HAN params: {n_params/1e6:.1f}M  edges: {sum(s.num_edges for s in sgs)}")

    opt = AdamWConfig(lr=5e-3, weight_decay=0.0)
    ostate = init_opt_state(params, opt)
    start = 0
    last = latest_step(args.ckpt)
    if last is not None:
        state, _ = restore_checkpoint(args.ckpt, last, {"params": params, "opt": ostate})
        params, ostate = state["params"], state["opt"]
        start = last
        print(f"resumed from step {last}")

    @jax.jit
    def step(p, s):
        loss, grads = jax.value_and_grad(
            lambda p_: cross_entropy(model.forward(p_, data, backend=NABackend.SEGMENT), data.labels)
        )(p)
        p, s, _ = apply_updates(p, grads, s, opt, jnp.asarray(5e-3))
        return p, s, loss

    t0 = time.time()
    for i in range(start, args.steps):
        params, ostate, loss = step(params, ostate)
        if i % 20 == 0:
            logits = model.forward(params, data)
            acc = float((jnp.argmax(logits, -1) == data.labels).mean())
            print(f"step {i:4d}  loss {float(loss):.4f}  acc {acc:.3f}  ({time.time()-t0:.1f}s)")
        if (i + 1) % 100 == 0:
            save_checkpoint(args.ckpt, i + 1, {"params": params, "opt": ostate})
    save_checkpoint(args.ckpt, args.steps, {"params": params, "opt": ostate})
    print("training complete")


if __name__ == "__main__":
    main()
