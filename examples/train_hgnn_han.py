"""End-to-end driver: train HAN (~100M-param config) for a few hundred
steps on synthetic ACM with checkpoint/resume.

    PYTHONPATH=src python examples/train_hgnn_han.py [--steps 300]

A thin veneer over the mesh-scale launcher (``repro.launch.hgnn_train``):
the model is widened (hidden 128 × 8 heads, att_dim 256, full-scale ACM
features) to ~100M parameters, trained full-batch (transductive node
classification, as HAN trains) through the consolidated multilane NA path
with the fault-tolerant train_loop — atomic checkpoints, counter-based
data state, elastic lane restarts.  Add ``--lanes 2`` (or set
``XLA_FLAGS=--xla_force_host_platform_device_count=4``) to shard the NA
work units over a lane mesh; the loss trajectory does not change.
"""
import argparse

from repro.launch.hgnn_train import run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--scale", type=float, default=0.5)
    ap.add_argument("--lanes", type=int, default=1)
    ap.add_argument(
        "--backend", default="kernel",
        choices=("reference", "kernel", "kernel_interpret"),
    )
    ap.add_argument("--ckpt", default="artifacts/han_ckpt")
    args = ap.parse_args()

    state, history, meta = run_training(
        dataset="acm",
        model_name="HAN",
        steps=args.steps,
        lanes=args.lanes,
        backend=args.backend,
        hidden=128,
        heads=8,
        scale=args.scale,
        feat_scale=1.0,
        ckpt_dir=args.ckpt,
        ckpt_every=100,
        log_every=20,
    )
    print(
        f"training complete: loss {history[0]['loss']:.4f} -> "
        f"{history[-1]['loss']:.4f}  acc {history[-1]['acc']:.3f}  "
        f"({meta['n_params']/1e6:.1f}M params, backend={meta['backend']})"
    )


if __name__ == "__main__":
    main()
