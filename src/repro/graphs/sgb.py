"""Semantic Graph Build (SGB) — stage 1 of the HGNN pipeline.

Builds semantic graphs from metapaths by composing relation edge lists.
The paper runs SGB on the host CPU in preprocessing (Section 3.1); we do
the same: numpy join-based sparse composition, deduplicated, with an
optional cap to bound blow-up on hub-heavy compositions (e.g. DBLP's PVP
generating ~20M edges from 14k papers through 20 venues).
"""
from __future__ import annotations

import numpy as np

from .hetgraph import HetGraph, Relation, SemanticGraph


def _compose(
    src_a: np.ndarray,
    mid_a: np.ndarray,
    mid_b: np.ndarray,
    dst_b: np.ndarray,
    *,
    max_edges: int | None = None,
    rng: np.random.Generator | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Compose edge lists (src->mid) ∘ (mid->dst) -> unique (src,dst) pairs.

    Join on the shared mid vertex: group both lists by mid id, emit the
    per-mid cross product.  Equivalent to boolean A@B on the adjacency
    matrices (property-tested against that oracle in tests/).
    """
    if src_a.size == 0 or mid_b.size == 0:
        return np.empty(0, np.int32), np.empty(0, np.int32)

    order_a = np.argsort(mid_a, kind="stable")
    order_b = np.argsort(mid_b, kind="stable")
    mid_a_s, src_a_s = mid_a[order_a], src_a[order_a]
    mid_b_s, dst_b_s = mid_b[order_b], dst_b[order_b]

    n_mid = int(max(mid_a_s[-1], mid_b_s[-1])) + 1
    cnt_a = np.bincount(mid_a_s, minlength=n_mid).astype(np.int64)
    cnt_b = np.bincount(mid_b_s, minlength=n_mid).astype(np.int64)
    start_a = np.concatenate([[0], np.cumsum(cnt_a)])
    start_b = np.concatenate([[0], np.cumsum(cnt_b)])

    pair_counts = cnt_a * cnt_b
    total = int(pair_counts.sum())
    if total == 0:
        return np.empty(0, np.int32), np.empty(0, np.int32)

    src_out = np.empty(total, np.int32)
    dst_out = np.empty(total, np.int32)
    pos = 0
    for m in np.nonzero(pair_counts)[0]:
        ca, cb = int(cnt_a[m]), int(cnt_b[m])
        block = ca * cb
        s = src_a_s[start_a[m] : start_a[m] + ca]
        d = dst_b_s[start_b[m] : start_b[m] + cb]
        src_out[pos : pos + block] = np.repeat(s, cb)
        dst_out[pos : pos + block] = np.tile(d, ca)
        pos += block

    # Dedupe (boolean semantics): unique (src, dst) pairs.
    key = src_out.astype(np.int64) * np.int64(2**31) + dst_out.astype(np.int64)
    _, idx = np.unique(key, return_index=True)
    src_out, dst_out = src_out[idx], dst_out[idx]

    if max_edges is not None and src_out.size > max_edges:
        rng = rng or np.random.default_rng(0)
        pick = rng.choice(src_out.size, size=max_edges, replace=False)
        pick.sort()
        src_out, dst_out = src_out[pick], dst_out[pick]
    return src_out, dst_out


def _find_relation(g: HetGraph, src_type: str, dst_type: str) -> Relation:
    for rel in g.relations.values():
        if rel.src_type == src_type and rel.dst_type == dst_type:
            return rel
    for rel in g.relations.values():  # fall back to a reversed relation
        if rel.src_type == dst_type and rel.dst_type == src_type:
            return rel.reversed()
    raise KeyError(f"no relation {src_type}->{dst_type}")


def build_semantic_graph(
    g: HetGraph,
    metapath: tuple[str, ...],
    *,
    max_edges: int | None = None,
    seed: int = 0,
) -> SemanticGraph:
    """Build one semantic graph from a metapath of vertex types, e.g.
    ('author','paper','author') — the APA co-author semantic graph."""
    assert len(metapath) >= 2
    rng = np.random.default_rng(seed)
    rel = _find_relation(g, metapath[0], metapath[1])
    src, dst = rel.src_ids, rel.dst_ids
    for hop in range(1, len(metapath) - 1):
        nxt = _find_relation(g, metapath[hop], metapath[hop + 1])
        src, dst = _compose(src, dst, nxt.src_ids, nxt.dst_ids, max_edges=max_edges, rng=rng)
    name = "".join(t[0].upper() for t in metapath)
    return SemanticGraph(
        name=name,
        src_type=metapath[0],
        dst_type=metapath[-1],
        src_ids=src,
        dst_ids=dst,
        num_src=g.num_vertices(metapath[0]),
        num_dst=g.num_vertices(metapath[-1]),
        path_types=tuple(metapath),
    )


def build_semantic_graphs(
    g: HetGraph,
    metapaths: list[tuple[str, ...]],
    *,
    max_edges: int | None = None,
) -> list[SemanticGraph]:
    return [build_semantic_graph(g, mp, max_edges=max_edges, seed=i) for i, mp in enumerate(metapaths)]
