"""Device-facing graph formats.

Two executable layouts for a SemanticGraph:

* ``PaddedEdges`` — dst-sorted edge list padded to a static length; drives
  the pure-jnp segment ops (the staged/unfused baseline path and the
  reference semantics).

* ``BlockCSR`` — the TPU-native layout: the (dst × src) adjacency is cut
  into B×B blocks (B = 128 aligns with the MXU); only non-empty blocks are
  kept, organized as block rows padded to a fixed number of blocks per row.
  This is the HiHGNN hardware adaptation: the accelerator streams edges
  through MSHR-backed SRAM buffers; on TPU the same irregular NA stage is
  *block-densified* so it runs as masked dense MXU/VPU work from VMEM tiles
  (see DESIGN.md §2).  The per-row block lists are what the fused
  online-softmax kernel (kernels/seg_gat_agg.py) iterates over.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .hetgraph import SemanticGraph


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class PaddedEdges:
    """dst-sorted edge list, padded to ``length`` with sentinel edges.

    Padding edges point at (src=num_src_pad-1 row of zeros is NOT assumed);
    instead ``valid`` masks them out of every aggregation.
    """

    src: np.ndarray  # int32 [E_pad]
    dst: np.ndarray  # int32 [E_pad]
    valid: np.ndarray  # bool [E_pad]
    num_src: int
    num_dst: int

    @property
    def num_edges(self) -> int:
        return int(self.valid.sum())


def to_padded_edges(sg: SemanticGraph, *, pad_to: int | None = None) -> PaddedEdges:
    order = np.argsort(sg.dst_ids, kind="stable")
    src = sg.src_ids[order]
    dst = sg.dst_ids[order]
    e = src.shape[0]
    e_pad = pad_to if pad_to is not None else max(_ceil_to(max(e, 1), 128), 128)
    assert e_pad >= e, (e_pad, e)
    pad = e_pad - e
    src = np.concatenate([src, np.zeros(pad, np.int32)])
    dst = np.concatenate([dst, np.full(pad, max(sg.num_dst - 1, 0), np.int32)])
    valid = np.concatenate([np.ones(e, bool), np.zeros(pad, bool)])
    return PaddedEdges(src=src, dst=dst, valid=valid, num_src=sg.num_src, num_dst=sg.num_dst)


@dataclasses.dataclass(frozen=True)
class BlockCSR:
    """Block-sparse adjacency: non-empty B×B blocks, padded per block row.

    ``col_index[i, j]`` is the src-block column of the j-th kept block in
    dst-block row i, or ``-1`` for padding (its mask slot is all-False).
    ``masks[i, j]`` is the dense B×B boolean adjacency of that block
    (mask[p, q] == edge (src = col*B + q  ->  dst = row*B + p)).
    """

    block: int
    num_dst_pad: int
    num_src_pad: int
    col_index: np.ndarray  # int32 [n_dst_blocks, max_blocks_per_row]
    masks: np.ndarray  # bool  [n_dst_blocks, max_blocks_per_row, B, B]
    num_edges: int

    @property
    def n_dst_blocks(self) -> int:
        return int(self.col_index.shape[0])

    @property
    def max_blocks_per_row(self) -> int:
        return int(self.col_index.shape[1])

    def density(self) -> float:
        """Fraction of kept block slots that are real (non-padding)."""
        return float((self.col_index >= 0).mean())


def to_block_csr(sg: SemanticGraph, *, block: int = 128, min_blocks_per_row: int = 1) -> BlockCSR:
    b = block
    nd_pad = _ceil_to(max(sg.num_dst, 1), b)
    ns_pad = _ceil_to(max(sg.num_src, 1), b)
    n_rows = nd_pad // b

    if sg.num_edges == 0:
        col_index = np.full((n_rows, min_blocks_per_row), -1, np.int32)
        masks = np.zeros((n_rows, min_blocks_per_row, b, b), bool)
        return BlockCSR(b, nd_pad, ns_pad, col_index, masks, 0)

    row_blk = sg.dst_ids // b
    col_blk = sg.src_ids // b
    key = row_blk.astype(np.int64) * (ns_pad // b) + col_blk
    uniq, inv = np.unique(key, return_inverse=True)
    u_rows = (uniq // (ns_pad // b)).astype(np.int32)
    u_cols = (uniq % (ns_pad // b)).astype(np.int32)

    blocks_per_row = np.bincount(u_rows, minlength=n_rows)
    width = max(int(blocks_per_row.max()), min_blocks_per_row)

    col_index = np.full((n_rows, width), -1, np.int32)
    masks = np.zeros((n_rows, width, b, b), bool)
    slot_of_block = np.empty(uniq.shape[0], np.int32)
    cursor = np.zeros(n_rows, np.int32)
    for k in range(uniq.shape[0]):
        r = u_rows[k]
        s = cursor[r]
        cursor[r] += 1
        col_index[r, s] = u_cols[k]
        slot_of_block[k] = s
    # scatter edges into their block masks
    masks[row_blk, slot_of_block[inv], sg.dst_ids % b, sg.src_ids % b] = True
    return BlockCSR(b, nd_pad, ns_pad, col_index, masks, sg.num_edges)


def block_csr_to_dense(bc: BlockCSR) -> np.ndarray:
    """Dense [num_dst_pad, num_src_pad] boolean adjacency (test oracle)."""
    b = bc.block
    out = np.zeros((bc.num_dst_pad, bc.num_src_pad), bool)
    for r in range(bc.n_dst_blocks):
        for j in range(bc.max_blocks_per_row):
            c = bc.col_index[r, j]
            if c >= 0:
                out[r * b : (r + 1) * b, c * b : (c + 1) * b] |= bc.masks[r, j]
    return out


def dense_adjacency(sg: SemanticGraph) -> np.ndarray:
    out = np.zeros((sg.num_dst, sg.num_src), bool)
    out[sg.dst_ids, sg.src_ids] = True
    return out
