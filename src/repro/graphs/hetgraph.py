"""Heterogeneous graph substrate (host-side, numpy).

A HetGraph G = (V, E, T_v, T_e) carries typed vertex sets with per-type
feature matrices and typed edge sets (relations).  Semantic graphs are
derived from it by metapath composition (see sgb.py) or taken per relation.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class Relation:
    """A typed edge set `src_type --name--> dst_type`."""

    name: str
    src_type: str
    dst_type: str
    src_ids: np.ndarray  # int32 [E]
    dst_ids: np.ndarray  # int32 [E]

    @property
    def num_edges(self) -> int:
        return int(self.src_ids.shape[0])

    def reversed(self, name: str | None = None) -> "Relation":
        return Relation(
            name=name or (self.name + "_rev"),
            src_type=self.dst_type,
            dst_type=self.src_type,
            src_ids=self.dst_ids,
            dst_ids=self.src_ids,
        )


@dataclasses.dataclass(frozen=True)
class HetGraph:
    """Typed vertices + typed edges + per-type raw features."""

    vertex_counts: Mapping[str, int]
    features: Mapping[str, np.ndarray]  # type -> float32 [n_type, d_type]
    relations: Mapping[str, Relation]

    @property
    def vertex_types(self) -> Sequence[str]:
        return tuple(self.vertex_counts.keys())

    @property
    def edge_types(self) -> Sequence[str]:
        return tuple(self.relations.keys())

    def num_vertices(self, vtype: str) -> int:
        return int(self.vertex_counts[vtype])

    def feature_dim(self, vtype: str) -> int:
        return int(self.features[vtype].shape[1])

    def validate(self) -> None:
        for name, rel in self.relations.items():
            assert rel.name == name
            assert rel.src_ids.shape == rel.dst_ids.shape
            assert rel.src_ids.dtype == np.int32 and rel.dst_ids.dtype == np.int32
            ns = self.vertex_counts[rel.src_type]
            nd = self.vertex_counts[rel.dst_type]
            if rel.num_edges:
                assert rel.src_ids.min() >= 0 and rel.src_ids.max() < ns, name
                assert rel.dst_ids.min() >= 0 and rel.dst_ids.max() < nd, name
        for vtype, feat in self.features.items():
            assert feat.shape[0] == self.vertex_counts[vtype], vtype


@dataclasses.dataclass(frozen=True)
class SemanticGraph:
    """One semantic graph G^P: edges src->dst under a metapath/relation P.

    ``path_types`` records every vertex type visited along the metapath —
    that is what similarity-aware scheduling (core/scheduling.py) uses to
    estimate inter-semantic-graph FP reuse, mirroring the paper's hypergraph
    whose edge weights come from shared vertex types.
    """

    name: str
    src_type: str
    dst_type: str
    src_ids: np.ndarray  # int32 [E]
    dst_ids: np.ndarray  # int32 [E]
    num_src: int
    num_dst: int
    path_types: tuple[str, ...]

    @property
    def num_edges(self) -> int:
        return int(self.src_ids.shape[0])

    def in_degrees(self) -> np.ndarray:
        return np.bincount(self.dst_ids, minlength=self.num_dst).astype(np.int32)


def make_relation(name, src_type, dst_type, src_ids, dst_ids) -> Relation:
    return Relation(
        name=name,
        src_type=src_type,
        dst_type=dst_type,
        src_ids=np.asarray(src_ids, np.int32),
        dst_ids=np.asarray(dst_ids, np.int32),
    )


def relation_semantic_graphs(g: HetGraph) -> list[SemanticGraph]:
    """One semantic graph per relation (the R-GCN / R-GAT / S-HGN view)."""
    out = []
    for rel in g.relations.values():
        out.append(
            SemanticGraph(
                name=rel.name,
                src_type=rel.src_type,
                dst_type=rel.dst_type,
                src_ids=rel.src_ids,
                dst_ids=rel.dst_ids,
                num_src=g.num_vertices(rel.src_type),
                num_dst=g.num_vertices(rel.dst_type),
                path_types=(rel.src_type, rel.dst_type),
            )
        )
    return out
