from .hetgraph import HetGraph, Relation, SemanticGraph, make_relation, relation_semantic_graphs
from .sgb import build_semantic_graph, build_semantic_graphs
from .formats import (
    BlockCSR,
    PaddedEdges,
    block_csr_to_dense,
    dense_adjacency,
    to_block_csr,
    to_padded_edges,
)
from .datasets import (
    TABLE5,
    dataset_metapaths,
    dataset_target,
    synthetic_hetgraph,
    synthetic_labels,
)

__all__ = [
    "HetGraph",
    "Relation",
    "SemanticGraph",
    "make_relation",
    "relation_semantic_graphs",
    "build_semantic_graph",
    "build_semantic_graphs",
    "BlockCSR",
    "PaddedEdges",
    "block_csr_to_dense",
    "dense_adjacency",
    "to_block_csr",
    "to_padded_edges",
    "TABLE5",
    "dataset_metapaths",
    "dataset_target",
    "synthetic_hetgraph",
    "synthetic_labels",
]
