"""Synthetic HetG generators matching the paper's Table 5 statistics.

Offline reproduction: IMDB / ACM / DBLP are regenerated as random HetGs
with the *exact* vertex counts, feature dims, per-relation edge counts and
metapath sets of Table 5.  A ``scale`` < 1 shrinks everything uniformly for
tests.  Degree distributions are skewed (Zipf-ish dst selection) to retain
the irregularity that makes the NA stage memory-bound.
"""
from __future__ import annotations

import numpy as np

from .hetgraph import HetGraph, make_relation

# Table 5 of the paper: vertices, feature dims, relations (edge counts), metapaths.
TABLE5 = {
    "imdb": {
        "vertices": {"movie": 4932, "director": 2393, "actor": 6124, "keyword": 7971},
        "features": {"movie": 3489, "director": 3341, "actor": 3341, "keyword": 64},
        "relations": {
            "AM": ("actor", "movie", 14779),
            "MA": ("movie", "actor", 14779),
            "KM": ("keyword", "movie", 23610),
            "MK": ("movie", "keyword", 23610),
            "DM": ("director", "movie", 4932),
            "MD": ("movie", "director", 4932),
        },
        "metapaths": [
            ("movie", "director", "movie"),
            ("movie", "actor", "movie"),
            ("movie", "keyword", "movie"),
        ],
        "target": "movie",
        "num_classes": 3,
    },
    "acm": {
        "vertices": {"paper": 3025, "author": 5959, "subject": 56, "term": 1902},
        "features": {"paper": 1902, "author": 1902, "subject": 1902, "term": 64},
        "relations": {
            "TP": ("term", "paper", 255619),
            "PT": ("paper", "term", 255619),
            "SP": ("subject", "paper", 3025),
            "PS": ("paper", "subject", 3025),
            "PP": ("paper", "paper", 5343),
            "AP": ("author", "paper", 9949),
            "PA": ("paper", "author", 9949),
        },
        "metapaths": [
            ("paper", "paper", "subject", "paper"),
            ("paper", "subject", "paper"),
            ("paper", "paper", "author", "paper"),
            ("paper", "author", "paper"),
        ],
        "target": "paper",
        "num_classes": 3,
    },
    "dblp": {
        "vertices": {"author": 4057, "paper": 14328, "term": 7723, "venue": 20},
        "features": {"author": 334, "paper": 4231, "term": 50, "venue": 64},
        "relations": {
            "AP": ("author", "paper", 19645),
            "PA": ("paper", "author", 19645),
            "VP": ("venue", "paper", 14328),
            "PV": ("paper", "venue", 14328),
            "TP": ("term", "paper", 85810),
            "PT": ("paper", "term", 85810),
        },
        "metapaths": [
            ("author", "paper", "author"),
            ("author", "paper", "term", "paper", "author"),
            ("author", "paper", "venue", "paper", "author"),
        ],
        "target": "author",
        "num_classes": 4,
    },
}


def _rand_edges(rng, n_src, n_dst, n_edges):
    """Random bipartite edges with Zipf-skewed dst degrees, deduped."""
    n_edges = min(n_edges, n_src * n_dst)
    # oversample then dedupe to land near the requested count
    m = int(n_edges * 1.3) + 8
    src = rng.integers(0, n_src, size=m).astype(np.int32)
    # skewed destination choice: mix uniform with a small hot set
    hot = max(1, n_dst // 16)
    pick_hot = rng.random(m) < 0.35
    dst = np.where(
        pick_hot,
        rng.integers(0, hot, size=m),
        rng.integers(0, n_dst, size=m),
    ).astype(np.int32)
    key = src.astype(np.int64) * n_dst + dst
    _, idx = np.unique(key, return_index=True)
    idx = idx[: n_edges]
    return src[idx], dst[idx]


def synthetic_hetgraph(
    name: str,
    *,
    scale: float = 1.0,
    feat_scale: float = 1.0,
    seed: int = 0,
) -> HetGraph:
    """Generate the named Table-5 dataset (scaled); deterministic in seed."""
    spec = TABLE5[name]
    rng = np.random.default_rng(seed)

    def sv(n):  # scale vertex counts, keep >= 4
        return max(4, int(round(n * scale)))

    def sf(d):  # scale feature dims, keep >= 8
        return max(8, int(round(d * feat_scale)))

    counts = {t: sv(n) for t, n in spec["vertices"].items()}
    feats = {
        t: rng.standard_normal((counts[t], sf(d))).astype(np.float32) * 0.1
        for t, d in spec["features"].items()
    }
    relations = {}
    for rname, (st, dt, ne) in spec["relations"].items():
        ne_s = max(4, int(round(ne * scale * scale))) if scale < 1.0 else ne
        if rname.endswith("_rev") or (rname[::-1] in relations and rname != rname[::-1]):
            # mirror of an already-generated relation -> exact reverse
            fwd = relations[rname[::-1]]
            relations[rname] = fwd.reversed(rname)
            continue
        s, d = _rand_edges(rng, counts[st], counts[dt], ne_s)
        relations[rname] = make_relation(rname, st, dt, s, d)

    g = HetGraph(vertex_counts=counts, features=feats, relations=relations)
    g.validate()
    return g


def dataset_metapaths(name: str) -> list[tuple[str, ...]]:
    return list(TABLE5[name]["metapaths"])


def dataset_target(name: str) -> tuple[str, int]:
    spec = TABLE5[name]
    return spec["target"], spec["num_classes"]


def synthetic_labels(g: HetGraph, name: str, seed: int = 0) -> np.ndarray:
    """Labels with planted structure: class = argmax over random projection
    of features, so models can actually fit them (loss decreases)."""
    target, ncls = dataset_target(name)
    rng = np.random.default_rng(seed + 1)
    x = g.features[target]
    w = rng.standard_normal((x.shape[1], ncls)).astype(np.float32)
    logits = x @ w + 0.1 * rng.standard_normal((x.shape[0], ncls)).astype(np.float32)
    return logits.argmax(-1).astype(np.int32)
