"""Serving engine: batched prefill + decode with carried caches.

``serve_step`` is the unit the decode_* / long_* dry-run cells lower: one
new token for every sequence in the batch against a KV cache of
``cache_len`` (full attention), a ring buffer (local attention) or O(1)
recurrent state (SSM / RG-LRU) — the sub-quadratic archs' long_500k cells
compile to context-independent state updates.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..models.lm import encdec
from ..models.lm.api import LMApi
from ..models.lm.transformer import mark_cache_filled


@dataclasses.dataclass
class ServeState:
    caches: Any
    cache_pos: jnp.ndarray  # scalar int32
    cross_kv: Any = None    # enc-dec only


jax.tree_util.register_pytree_node(
    ServeState,
    lambda s: ((s.caches, s.cache_pos, s.cross_kv), None),
    lambda _, ch: ServeState(*ch),
)


def init_serve_state(
    api: LMApi, batch: int, cache_len: int, *, dtype=jnp.bfloat16, filled: int = 0
) -> ServeState:
    caches = api.init_caches(batch, cache_len, dtype)
    if filled:
        caches = mark_cache_filled(caches, filled)
    cross = None
    if api.cfg.is_encoder_decoder:
        # placeholder cross-KV until prefill computes it from real frames
        cross = (
            jnp.zeros(
                (api.cfg.num_layers, batch, api.cfg.encoder_seq, api.cfg.num_kv_heads, api.cfg.head_dim),
                dtype,
            ),
            jnp.zeros(
                (api.cfg.num_layers, batch, api.cfg.encoder_seq, api.cfg.num_kv_heads, api.cfg.head_dim),
                dtype,
            ),
        )
    return ServeState(caches=caches, cache_pos=jnp.asarray(filled, jnp.int32), cross_kv=cross)


def make_serve_step(api: LMApi) -> Callable:
    """(params, state, tokens [B,1]) -> (logits [B, vocab_pad], state)."""
    cfg = api.cfg

    def serve_step(params, state: ServeState, tokens: jnp.ndarray):
        kw = {}
        if cfg.is_encoder_decoder:
            kw["cross_kv"] = state.cross_kv
        logits, caches = api.decode(params, tokens, state.cache_pos, state.caches, **kw)
        return logits[:, 0], ServeState(
            caches=caches, cache_pos=state.cache_pos + 1, cross_kv=state.cross_kv
        )

    return serve_step


def make_prefill(api: LMApi) -> Callable:
    """(params, state, tokens [B,S]) -> (last logits, state) — fills caches
    by running decode steps under a scan (correct for every cache family)."""
    serve_step = make_serve_step(api)
    cfg = api.cfg

    def prefill(params, state: ServeState, tokens: jnp.ndarray, frames=None):
        if cfg.is_encoder_decoder:
            enc_out = encdec.encode(params, cfg, frames)
            cross = encdec.precompute_cross(params, cfg, enc_out)
            state = ServeState(caches=state.caches, cache_pos=state.cache_pos, cross_kv=cross)

        def step(carry, tok):
            st = carry
            logits, st = serve_step(params, st, tok[:, None])
            return st, logits

        state, logits_all = jax.lax.scan(step, state, tokens.T)
        return logits_all[-1], state

    return prefill


def greedy_generate(api: LMApi, params, prompt: jnp.ndarray, steps: int, cache_len: int):
    """Simple batched greedy decoding (examples/serve_lm.py)."""
    b = prompt.shape[0]
    state = init_serve_state(api, b, cache_len, dtype=jnp.float32)
    prefill = make_prefill(api)
    serve_step = make_serve_step(api)
    kw = {}
    if api.cfg.is_encoder_decoder:
        kw["frames"] = jnp.zeros((b, api.cfg.encoder_seq, api.cfg.d_model), jnp.float32)
    logits, state = prefill(params, state, prompt, **kw)
    out = []
    tok = jnp.argmax(logits[:, : api.cfg.vocab_size], axis=-1).astype(jnp.int32)
    for _ in range(steps):
        out.append(tok)
        logits, state = serve_step(params, state, tok[:, None])
        tok = jnp.argmax(logits[:, : api.cfg.vocab_size], axis=-1).astype(jnp.int32)
    return jnp.stack(out, axis=1)
