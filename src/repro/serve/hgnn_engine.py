"""HGNN serving engine: stepped graph-request execution over a resident
HetGraph with a cross-request FP cache and similarity-aware admission.

This is the paper's inter-semantic-graph data reusability (§4.3) promoted
to the serving tier.  Concurrent requests — vertex-type-tagged subgraph
queries, each a set of metapaths whose endpoints are the resident target
type — occupy a fixed-slot batch.  Each engine step executes ONE semantic
graph per occupied slot:

1. **FP** — the projected tables of every vertex type on the step's
   metapaths are materialized through the shared :class:`FPCache`
   (``serve/fp_cache.py``): blocks left behind by previous requests (or
   by co-batched slots this step) are reused, the rest computed.  This is
   ``core/reuse.py:fp_buffer_traffic``'s working-set accounting, measured
   instead of modeled.
2. **NA** — attention coefficients from the target-type table, then ONE
   fused multigraph launch for all slots' semantic graphs
   (``fusion.neighbor_aggregate_multi``, ``backend=MULTIGRAPH`` on TPU /
   ``MULTIGRAPH_INTERPRET`` on CPU; the non-multigraph backends fall back
   to a per-graph loop with identical semantics).
3. **LSF/GSF** — per-graph semantic importances accumulate on the slot;
   when a request's last metapath completes, global semantic fusion
   produces its embedding and the slot is freed for the queue.

Admission is similarity-aware by default: the queue is ordered by the
shortest Hamilton path over ``core/scheduling.py:similarity_matrix``
computed on the *request* mix (requests expose ``path_types`` exactly
like semantic graphs), anchored at the end that overlaps the cache's
resident types most — so co-batched and consecutive requests share FP
blocks.  ``admission="fifo"`` is the ablation baseline.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from collections import Counter
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core import stages
from ..core.fusion import (
    _FUSED_FP_BACKENDS,
    _FUSED_TO_MULTIGRAPH,
    FusedFPInputs,
    NABackend,
    SemanticGraphBatch,
    batch_semantic_graph,
    neighbor_aggregate_multi,
)
from ..core.reuse import FPTraffic, fp_buffer_traffic
from ..core.scheduling import shortest_hamilton_path, similarity_matrix
from ..graphs.hetgraph import HetGraph
from ..graphs.sgb import build_semantic_graph
from ..models.hgnn.common import glorot
from ..obs.metrics import MetricsRegistry
from ..obs.trace import trace_span
from .fp_cache import FPCache


@dataclasses.dataclass
class GraphRequest:
    """A vertex-type-tagged subgraph query: run the given metapaths (all
    endpoints = the engine's target type) and return the fused embedding."""

    rid: int
    metapaths: list[tuple[str, ...]]
    submitted_step: int = -1
    admitted_step: int = -1
    finished_step: int = -1
    result: jnp.ndarray | None = None   # [N_target, H*Dh] on finish
    beta: jnp.ndarray | None = None     # [G] semantic attention on finish
    _progress: int = 0
    _z: list = dataclasses.field(default_factory=list, repr=False)
    _w: list = dataclasses.field(default_factory=list, repr=False)

    @property
    def path_types(self) -> tuple[str, ...]:
        """Stable-unique union of vertex types across the metapaths — the
        request's FP working set (what similarity admission scores)."""
        seen: dict[str, None] = {}
        for mp in self.metapaths:
            for t in mp:
                seen.setdefault(t)
        return tuple(seen)

    @property
    def done(self) -> bool:
        return self._progress >= len(self.metapaths)


def _stable_seed(name: str) -> int:
    return int.from_bytes(hashlib.blake2b(name.encode(), digest_size=4).digest(), "big")


class HGNNEngine:
    """Fixed-slot stepped HGNN inference over a resident HetGraph."""

    def __init__(
        self,
        graph: HetGraph,
        *,
        target_type: str,
        hidden: int = 8,
        heads: int = 2,
        att_dim: int = 16,
        num_slots: int = 2,
        cache_bytes: int = 1 << 20,
        cache_block_rows: int = 128,
        cache_policy: str = "lru",
        admission: str = "similarity",
        backend: NABackend = NABackend.MULTIGRAPH,
        block: int = 16,
        max_edges: int | None = 20_000,
        seed: int = 0,
        registry: MetricsRegistry | None = None,
    ):
        assert admission in ("similarity", "fifo"), admission
        assert target_type in graph.vertex_counts, target_type
        self.graph = graph
        self.target_type = target_type
        self.hidden, self.heads, self.att_dim = hidden, heads, att_dim
        self.num_slots = num_slots
        self.admission = admission
        self.backend = backend
        self.block = block
        self.max_edges = max_edges
        self.n_target = graph.num_vertices(target_type)

        self.features = {t: jnp.asarray(x) for t, x in graph.features.items()}
        self.cache = FPCache(cache_bytes, block_rows=cache_block_rows, policy=cache_policy)
        self.params = self._init_params(jax.random.key(seed))
        self._mp_key = jax.random.key(seed + 1)
        self._mp_params: dict[tuple[str, ...], tuple[jnp.ndarray, jnp.ndarray]] = {}
        self._batches: dict[tuple[str, ...], SemanticGraphBatch] = {}

        self.queue: list[GraphRequest] = []
        self.slots: list[GraphRequest | None] = [None] * num_slots
        self.finished: list[GraphRequest] = []
        self.steps_run = 0
        self.na_launches = 0
        self.fp_rows_naive = 0  # rows a recompute-per-request FP stage would project
        self.fused_steps = 0           # steps served by the FP+NA megakernel
        self.fused_cache_bypasses = 0  # fused steps downgraded: table already cached

        # Observability (DESIGN.md §12).  Each engine owns a private
        # registry by default so two engines in one process (e.g. the
        # --compare ablation) never mix series; pass a shared registry to
        # aggregate.  ``_executed`` records, per step, the stable-unique
        # tuple of vertex types projected through the cache — the input
        # the analytical FP-traffic model replays in ``fp_model_drift``.
        self.registry = registry if registry is not None else MetricsRegistry()
        self._executed: list[tuple[str, ...]] = []
        for k in sorted(self._COUNTER_KEYS):  # series exist from step zero
            self.registry.counter(f"serve.{k}")

    # -- parameters ---------------------------------------------------------

    def _init_params(self, rng: jax.Array) -> dict:
        keys = jax.random.split(rng, 3 + len(self.graph.vertex_counts))
        out_dim = self.heads * self.hidden
        w_fp = {}
        for i, t in enumerate(sorted(self.graph.vertex_counts)):
            w_fp[t] = glorot(keys[3 + i], (self.graph.feature_dim(t), out_dim))
        return {
            "w_fp": w_fp,
            "b_fp": {t: jnp.zeros((out_dim,)) for t in self.graph.vertex_counts},
            "w_g": glorot(keys[0], (out_dim, self.att_dim)),
            "b_g": jnp.zeros((self.att_dim,)),
            "q": glorot(keys[1], (self.att_dim, 1))[:, 0],
        }

    def _metapath_params(self, mp: tuple[str, ...]) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Per-metapath GAT vectors, deterministic in the metapath name so
        identical metapaths share parameters across requests and engines."""
        if mp not in self._mp_params:
            k = jax.random.fold_in(self._mp_key, _stable_seed("/".join(mp)))
            k1, k2 = jax.random.split(k)
            self._mp_params[mp] = (
                glorot(k1, (self.heads, self.hidden)),
                glorot(k2, (self.heads, self.hidden)),
            )
        return self._mp_params[mp]

    def _batch(self, mp: tuple[str, ...]) -> SemanticGraphBatch:
        """Device-resident semantic graph for a metapath (host-built once,
        memoized — SGB is preprocessing, as in the paper)."""
        if mp not in self._batches:
            sg = build_semantic_graph(
                self.graph, mp, max_edges=self.max_edges, seed=_stable_seed("/".join(mp))
            )
            self._batches[mp] = batch_semantic_graph(sg, block=self.block)
        return self._batches[mp]

    # -- request lifecycle --------------------------------------------------

    def submit(self, req: GraphRequest) -> None:
        assert req.metapaths, "empty request"
        for mp in req.metapaths:
            assert mp[0] == self.target_type and mp[-1] == self.target_type, (
                f"metapath {mp} endpoints must be the resident target type "
                f"{self.target_type!r} (shared dst space for the fused launch)"
            )
            for t in mp:
                assert t in self.graph.vertex_counts, t
        req.submitted_step = self.steps_run
        self.queue.append(req)

    def _admission_order(self) -> list[int]:
        n = len(self.queue)
        if self.admission == "fifo" or n <= 1:
            return list(range(n))
        w = similarity_matrix(self.queue, self.graph.vertex_counts)
        if n <= 12:
            order, _ = shortest_hamilton_path(w)
        else:
            # greedy nearest-neighbor chain (Held-Karp is 2^n)
            order = [0]
            rest = set(range(1, n))
            while rest:
                last = order[-1]
                order.append(min(rest, key=lambda j: w[last, j]))
                rest.remove(order[-1])
        # anchor the chain at the end overlapping the resident cache most
        resident = self.cache.resident_types()

        def overlap(i: int) -> int:
            return sum(
                self.graph.vertex_counts[t]
                for t in set(self.queue[i].path_types) & resident
            )

        if overlap(order[-1]) > overlap(order[0]):
            order.reverse()
        return order

    def _admit(self) -> None:
        if self.queue:
            order = self._admission_order()
            self.queue = [self.queue[i] for i in order]
            for s in range(self.num_slots):
                if self.slots[s] is None and self.queue:
                    req = self.queue.pop(0)
                    req.admitted_step = self.steps_run
                    self.slots[s] = req
        # refresh eviction demand: FP types still wanted by waiting +
        # in-flight work (similarity-weighted policy only reads this)
        demand: Counter[str] = Counter()
        for req in self.queue:
            demand.update(req.path_types)
        for req in self.slots:
            if req is not None:
                for mp in req.metapaths[req._progress :]:
                    demand.update(set(mp))
        self.cache.set_demand(demand)

    # -- execution ----------------------------------------------------------

    def _fp_tables(
        self, active: list[tuple[int, GraphRequest]], skip: set[str] = frozenset()
    ) -> dict[str, jnp.ndarray]:
        """Projected tables for the step's metapath types via the cache.
        ``skip`` types still count toward the naive-FP baseline but are
        neither projected nor admitted — the fused path projects the
        target type inside the NA launch instead."""
        tables: dict[str, jnp.ndarray] = {}
        with trace_span("serve/fp", stage="FP", step=self.steps_run) as sp:
            for _, req in active:
                mp = req.metapaths[req._progress]
                for t in dict.fromkeys(mp):
                    self.fp_rows_naive += self.graph.num_vertices(t)
                    if t not in tables and t not in skip:
                        tables[t] = sp.sync(
                            self.cache.project(
                                t,
                                self.features[t],
                                self.params["w_fp"][t],
                                self.params["b_fp"][t],
                            )
                        )
            sp.annotate(types=list(tables))
        self._executed.append(tuple(tables))
        return tables

    def step(self) -> int:
        """One engine step: admit, then execute one semantic graph per
        occupied slot (single fused NA launch).  Returns #active slots."""
        self._admit()
        active = [(s, r) for s, r in enumerate(self.slots) if r is not None]
        if not active:
            return 0
        t0 = time.perf_counter()
        with trace_span("serve/step", step=self.steps_run, slots=len(active)):
            self._step_body(active)
        self.registry.histogram("serve.step_ms").observe(
            (time.perf_counter() - t0) * 1e3
        )
        self._sync_registry()
        return len(active)

    def _step_body(self, active: list[tuple[int, GraphRequest]]) -> None:
        # Bound-aware dispatch for the fused-FP backend: if the cache
        # already holds the target type's whole projected table, FP is a
        # sunk cost — take the projected (multigraph) path and serve the
        # hit.  On a miss, the megakernel projects raw features on-chip
        # and h' never round-trips through HBM (nothing is admitted).
        backend = self.backend
        fused = backend in _FUSED_FP_BACKENDS
        if fused and self.cache.table_coverage(self.target_type, self.n_target) >= 1.0:
            backend = _FUSED_TO_MULTIGRAPH[backend]
            fused = False
            self.fused_cache_bypasses += 1
            self.registry.counter("serve.fused_cache_bypasses").inc()

        graph_names = ["/".join(r.metapaths[r._progress]) for _, r in active]
        if fused:
            self._fp_tables(active, skip={self.target_type})
            batches, a_s, a_d = [], [], []
            for _, req in active:
                mp = req.metapaths[req._progress]
                a_src, a_dst = self._metapath_params(mp)
                batches.append(self._batch(mp))
                a_s.append(a_src)
                a_d.append(a_dst)
            fp = FusedFPInputs.shared(
                self.features[self.target_type],
                self.params["w_fp"][self.target_type],
                self.params["b_fp"][self.target_type],
                jnp.stack(a_s),
                jnp.stack(a_d),
            )
            with trace_span(
                "serve/na", stage="NA", backend=backend.value,
                graphs=len(active), graph_names=graph_names, fused_fp=True,
            ) as sp:
                z_all = sp.sync(
                    neighbor_aggregate_multi(
                        batches, None, None, None, backend=backend, fp=fp
                    )
                )  # [G_active, N, H, Dh]
            self.fused_steps += 1
            self.registry.counter("serve.fused_steps").inc()
        else:
            tables = self._fp_tables(active)
            hh = tables[self.target_type].reshape(self.n_target, self.heads, self.hidden)

            batches, th_s, th_d = [], [], []
            with trace_span("serve/theta", stage="theta", graphs=len(active)) as sp:
                for _, req in active:
                    mp = req.metapaths[req._progress]
                    a_src, a_dst = self._metapath_params(mp)
                    ts, td = stages.attention_coefficients(hh, a_src, a_dst)
                    batches.append(self._batch(mp))
                    th_s.append(sp.sync(ts))
                    th_d.append(sp.sync(td))
            with trace_span(
                "serve/na", stage="NA", backend=backend.value,
                graphs=len(active), graph_names=graph_names,
            ) as sp:
                z_all = sp.sync(
                    neighbor_aggregate_multi(
                        batches, jnp.stack(th_s), jnp.stack(th_d), hh, backend=backend
                    )
                )  # [G_active, N, H, Dh]
        self.na_launches += 1
        self.registry.counter("serve.na_launches").inc()

        valid = jnp.ones((self.n_target,), bool)
        for i, (s, req) in enumerate(active):
            with trace_span(
                f"serve/fa/slot{s}", stage="FA", lane=f"slot{s}",
                rid=req.rid, graph=graph_names[i],
            ) as sp:
                z = jax.nn.elu(z_all[i].reshape(self.n_target, -1))
                w_p = sp.sync(
                    stages.local_semantic_fusion(
                        z, self.params["w_g"], self.params["b_g"], self.params["q"], valid
                    )
                )
                req._z.append(z)
                req._w.append(w_p)
                req._progress += 1
                if req.done:
                    fused_z, beta = stages.global_semantic_fusion(
                        jnp.stack(req._w), jnp.stack(req._z)
                    )
                    req.result, req.beta = sp.sync(fused_z), beta
                    req._z, req._w = [], []
                    req.finished_step = self.steps_run
                    self.finished.append(req)
                    self.slots[s] = None
                    self.registry.counter("serve.requests_finished").inc()
        self.steps_run += 1
        self.registry.counter("serve.steps").inc()

    def run(self, max_steps: int = 10_000) -> list[GraphRequest]:
        steps = 0
        while (self.queue or any(r is not None for r in self.slots)) and steps < max_steps:
            self.step()
            steps += 1
        return self.finished

    # -- coherence ----------------------------------------------------------

    def update_features(self, vtype: str, x: np.ndarray) -> None:
        """Install new raw features for ``vtype``.  Coherence rule
        (DESIGN.md §9): the cache version for the type is bumped and its
        blocks dropped, so no request ever reads a stale projection."""
        assert x.shape[0] == self.graph.num_vertices(vtype), vtype
        assert x.shape[1] == self.graph.feature_dim(vtype), vtype
        self.features[vtype] = jnp.asarray(x)
        self.cache.invalidate(vtype)

    # -- metrics ------------------------------------------------------------

    def traffic(self) -> FPTraffic:
        """Measured FP traffic in ``core/reuse.py``'s own accounting type."""
        return self.cache.stats.traffic()

    def fp_model_drift(self) -> dict:
        """Predicted-vs-measured FP traffic: replay the executed per-step
        type sets through ``core/reuse.py:fp_buffer_traffic`` (LRU buffer
        = this cache's capacity) and compare fetched bytes against what
        the block-granular cache actually fetched.  ``drift`` is
        measured/modeled fetched bytes — 1.0 means the paper's analytical
        FP-Buf model predicts the live traffic exactly; block-granular
        partial hits and similarity eviction push it below 1.0."""
        out_bytes = self.heads * self.hidden * 4  # f32 projected row

        class _Step:
            def __init__(self, pt):
                self.path_types = pt

        sgs = [_Step(pt) for pt in self._executed]
        model = fp_buffer_traffic(
            list(range(len(sgs))),
            sgs,
            self.graph.vertex_counts,
            bytes_per_vertex={t: out_bytes for t in self.graph.vertex_counts},
            fpbuf_bytes=self.cache.capacity_bytes,
        )
        measured = self.traffic()
        return dict(
            fp_model_fetched_bytes=model.fetched_bytes,
            fp_model_reused_bytes=model.reused_bytes,
            fp_measured_fetched_bytes=measured.fetched_bytes,
            fp_model_drift=measured.fetched_bytes / max(model.fetched_bytes, 1),
        )

    # counters maintained monotonically at event sites in step(); every
    # other metrics() key is mirrored into the registry as a gauge.
    _COUNTER_KEYS = frozenset(
        ("steps", "na_launches", "requests_finished", "fused_steps",
         "fused_cache_bypasses")
    )

    def _sync_registry(self) -> None:
        for k, v in self.metrics().items():
            if k not in self._COUNTER_KEYS:
                self.registry.gauge(f"serve.{k}").set(float(v))

    def metrics(self) -> dict:
        st = self.cache.stats
        return dict(
            steps=self.steps_run,
            na_launches=self.na_launches,
            requests_finished=len(self.finished),
            requests_waiting=len(self.queue),
            cache_hits=st.hits,
            cache_misses=st.misses,
            cache_hit_rate=st.hit_rate,
            reused_bytes=st.reused_bytes,
            fetched_bytes=st.fetched_bytes,
            reuse_fraction=st.reuse_fraction,
            evicted_bytes=st.evicted_bytes,
            fp_rows_computed=st.rows_computed,
            fp_rows_reused=st.rows_reused,
            fp_rows_naive=self.fp_rows_naive,
            fp_compute_reduction=self.fp_rows_naive / max(st.rows_computed, 1),
            fused_steps=self.fused_steps,
            fused_cache_bypasses=self.fused_cache_bypasses,
            cache_resident_bytes=self.cache.resident_bytes,
            cache_capacity_bytes=self.cache.capacity_bytes,
            **self.fp_model_drift(),
        )


def make_request_mix(
    rid_start: int,
    clusters: Sequence[Sequence[tuple[str, ...]]],
    repeats: int,
    *,
    interleave: bool = True,
) -> list[GraphRequest]:
    """Request mix builder used by benchmarks/tests: ``repeats`` requests
    per metapath cluster, interleaved round-robin (the adversarial arrival
    order for FIFO admission) or grouped."""
    reqs: list[GraphRequest] = []
    rid = rid_start
    if interleave:
        for _ in range(repeats):
            for cl in clusters:
                reqs.append(GraphRequest(rid=rid, metapaths=[tuple(m) for m in cl]))
                rid += 1
    else:
        for cl in clusters:
            for _ in range(repeats):
                reqs.append(GraphRequest(rid=rid, metapaths=[tuple(m) for m in cl]))
                rid += 1
    return reqs
