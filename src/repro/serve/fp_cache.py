"""Cross-request projected-feature (FP) block cache.

The paper's FP-Buf (§4.3.1) keeps projected feature tables resident so
the next semantic graph reuses them instead of re-fetching from HBM.
``core/reuse.py:fp_buffer_traffic`` *models* that traffic; this module is
the same idea made operational at the serving tier: a capacity-bounded
cache of projected-feature **row blocks**, keyed by
``(vertex_type, block_index, version)``, shared across concurrent graph
requests.  A request's FP stage projects only the blocks the cache does
not hold; everything else is served from cache — so ``reused_bytes`` /
``fetched_bytes`` here are *measured* counterparts of the model's
``FPTraffic`` accounting.

Block granularity (``block_rows`` vertices per block) is what lets a
buffer smaller than one type's full table still help: the resident
prefix is reused and only the missing blocks are recomputed — the
partial-block refetch the analytical model also implements.

Eviction policies:

* ``lru``        — least-recently-used block first.
* ``similarity`` — similarity-weighted: evict the block whose vertex
  type has the least demand from the pending request queue (the engine
  refreshes demand each admission round via :meth:`set_demand`);
  ties fall back to LRU order.

Coherence: when a vertex type's raw features (or its projection weights)
change, :meth:`invalidate` bumps that type's version and drops its
blocks — entries under the old version can never be served again
(DESIGN.md §9).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Mapping

import jax
import jax.numpy as jnp

from ..core import stages
from ..core.reuse import FPTraffic


@dataclasses.dataclass
class FPCacheStats:
    """Measured counterpart of ``core/reuse.py:FPTraffic``."""

    hits: int = 0
    misses: int = 0
    reused_bytes: int = 0
    fetched_bytes: int = 0
    evicted_bytes: int = 0
    rows_reused: int = 0
    rows_computed: int = 0
    invalidations: int = 0

    @property
    def hit_rate(self) -> float:
        return self.hits / max(self.hits + self.misses, 1)

    @property
    def reuse_fraction(self) -> float:
        return self.reused_bytes / max(self.reused_bytes + self.fetched_bytes, 1)

    def traffic(self) -> FPTraffic:
        """The measured FP traffic in the analytical model's own type."""
        return FPTraffic(reused_bytes=self.reused_bytes, fetched_bytes=self.fetched_bytes)


# One compiled program per (block shape, weight shape); shared by the
# cached and uncached paths so outputs are bit-identical either way.
_project_block = jax.jit(stages.feature_projection)


class FPCache:
    """Capacity-bounded cache of projected-feature row blocks."""

    def __init__(
        self,
        capacity_bytes: int,
        *,
        block_rows: int = 128,
        policy: str = "lru",
    ):
        assert policy in ("lru", "similarity"), policy
        assert capacity_bytes >= 0 and block_rows > 0
        self.capacity_bytes = int(capacity_bytes)
        self.block_rows = int(block_rows)
        self.policy = policy
        # key -> block, in LRU order (oldest first)
        self._blocks: OrderedDict[tuple[str, int, int], jnp.ndarray] = OrderedDict()
        self._bytes = 0
        self._version: dict[str, int] = {}
        self._demand: dict[str, float] = {}
        self.stats = FPCacheStats()

    # -- introspection ------------------------------------------------------

    @property
    def resident_bytes(self) -> int:
        return self._bytes

    @property
    def num_blocks(self) -> int:
        return len(self._blocks)

    def resident_types(self) -> set[str]:
        return {k[0] for k in self._blocks}

    def version(self, vtype: str) -> int:
        return self._version.get(vtype, 0)

    def table_coverage(self, vtype: str, num_rows: int) -> float:
        """Fraction of ``vtype``'s projected table (``num_rows`` rows)
        resident at the current version.  The serving engine's fused-FP
        path uses this for its bound-aware dispatch: coverage 1.0 means
        the projected table is already paid for, so running the FP stage
        again inside the megakernel would only waste FLOPs."""
        ver = self.version(vtype)
        br = self.block_rows
        n_blocks = (num_rows + br - 1) // br
        if n_blocks == 0:
            return 1.0
        resident = sum(
            min(br, num_rows - bi * br)
            for bi in range(n_blocks)
            if (vtype, bi, ver) in self._blocks
        )
        return resident / num_rows

    # -- coherence ----------------------------------------------------------

    def invalidate(self, vtype: str) -> None:
        """Coherence rule: raw features / projection weights of ``vtype``
        changed.  Bump the version (old-version keys can never match) and
        drop the now-stale blocks eagerly."""
        self._version[vtype] = self.version(vtype) + 1
        for key in [k for k in self._blocks if k[0] == vtype]:
            self._drop(key)
        self.stats.invalidations += 1

    # -- admission / eviction ----------------------------------------------

    def set_demand(self, demand: Mapping[str, float]) -> None:
        """Per-type demand of the pending queue (for the similarity-weighted
        eviction policy).  Refreshed by the engine each admission round."""
        self._demand = dict(demand)

    def _drop(self, key) -> None:
        blk = self._blocks.pop(key)
        nbytes = int(blk.size) * blk.dtype.itemsize
        self._bytes -= nbytes
        self.stats.evicted_bytes += nbytes

    def _victim(self):
        if self.policy == "lru":
            return next(iter(self._blocks))
        # similarity-weighted: least queue demand first; min() scans in
        # OrderedDict (LRU) order, so ties resolve to the oldest block
        return min(self._blocks, key=lambda k: self._demand.get(k[0], 0.0))

    def _insert(self, key, blk: jnp.ndarray) -> None:
        nbytes = int(blk.size) * blk.dtype.itemsize
        if nbytes > self.capacity_bytes:
            return  # a single block larger than the cache streams through
        while self._bytes + nbytes > self.capacity_bytes and self._blocks:
            self._drop(self._victim())
        self._blocks[key] = blk
        self._bytes += nbytes

    # -- the FP stage -------------------------------------------------------

    def project(
        self,
        vtype: str,
        x: jnp.ndarray,   # [N, Din] raw features
        w: jnp.ndarray,   # [Din, H*Dh]
        b: jnp.ndarray | None = None,
    ) -> jnp.ndarray:
        """Projected table ``x @ w + b`` for ``vtype``, block by block:
        resident blocks are served from cache, missing blocks computed and
        admitted.  Both paths run the same jitted block program, so the
        result is bit-identical to uncached recomputation."""
        ver = self.version(vtype)
        n = int(x.shape[0])
        br = self.block_rows
        out = []
        for bi in range((n + br - 1) // br):
            key = (vtype, bi, ver)
            blk = self._blocks.get(key)
            rows = min(br, n - bi * br)
            if blk is not None:
                self._blocks.move_to_end(key)
                nbytes = int(blk.size) * blk.dtype.itemsize
                self.stats.hits += 1
                self.stats.reused_bytes += nbytes
                self.stats.rows_reused += rows
            else:
                blk = _project_block(x[bi * br : bi * br + rows], w, b)
                nbytes = int(blk.size) * blk.dtype.itemsize
                self.stats.misses += 1
                self.stats.fetched_bytes += nbytes
                self.stats.rows_computed += rows
                self._insert(key, blk)
            out.append(blk)
        return out[0] if len(out) == 1 else jnp.concatenate(out, axis=0)
