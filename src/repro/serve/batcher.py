"""Continuous batching: slot-based request scheduling over a fixed batch.

Production serving keeps the decode batch full by admitting new requests
into slots as old ones finish — the decode step itself never recompiles
(static shapes).  Per-slot position counters ride in the cache `pos`
arrays (attention masks are per-slot valid-position tests, so slots at
different depths coexist in one batched step).

This is the HiHGNN workload-balance idea at the serving layer: slots are
lanes, the admission queue is the overflow-workload list, and the
scheduler keeps every lane busy.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..models.lm.api import LMApi
from .engine import ServeState, init_serve_state, make_serve_step


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int
    out: list[int] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.out) >= self.max_new


class ContinuousBatcher:
    """Fixed-slot continuous batcher (greedy decoding).

    Limitations of this reference implementation: prompts are injected by
    stepping them token-by-token through the slot (prefill == decode
    path), which is latency-suboptimal but keeps one compiled program;
    a production variant would add a separate batched prefill program.
    """

    def __init__(self, api: LMApi, num_slots: int, cache_len: int, params):
        self.api = api
        self.params = params
        self.num_slots = num_slots
        self.cache_len = cache_len
        # per-slot serving state: independent caches stacked on batch dim
        self.state = init_serve_state(api, num_slots, cache_len, dtype=jnp.float32)
        self.slot_req: list[Request | None] = [None] * num_slots
        self.slot_pos = np.zeros(num_slots, np.int64)  # per-slot abs position
        self.slot_pending: list[list[int]] = [[] for _ in range(num_slots)]
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self._step = self._build_step()

    def _build_step(self) -> Callable:
        serve = make_serve_step(self.api)
        cfg = self.api.cfg

        def step(params, state: ServeState, tokens, slot_positions):
            # per-slot positions: we step all slots with the *max* position
            # as cache_pos and rely on the per-slot pos arrays in the cache
            # for masking; slots write at their own ring positions via the
            # shared counter. Reference impl: one shared counter (slots
            # admitted at the current global position).
            logits, new_state = serve(params, state, tokens)
            nxt = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1).astype(jnp.int32)
            return nxt, new_state

        return jax.jit(step)

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _reset_slot(self, s: int) -> None:
        """Invalidate slot s's cache rows so a newly admitted request never
        attends to the previous occupant (pos -1 == masked; states zeroed).
        The slot (batch) dim is located by size: dim 1 for scan-stacked
        leaves [n_layers, B, ...], dim 0 for unstacked [B, ...]."""
        B = self.num_slots

        def reset(x):
            dim = 1 if x.ndim > 1 and x.shape[1] == B and x.shape[0] != B else 0
            if x.shape[dim] != B:
                return x
            idx = (slice(None),) * dim + (s,)
            if jnp.issubdtype(x.dtype, jnp.integer):
                return x.at[idx].set(-1)
            return x.at[idx].set(0)

        self.state = ServeState(
            caches=jax.tree_util.tree_map(reset, self.state.caches),
            cache_pos=self.state.cache_pos,
            cross_kv=self.state.cross_kv,
        )

    def _admit(self) -> None:
        for s in range(self.num_slots):
            if self.slot_req[s] is None and self.queue:
                req = self.queue.pop(0)
                self._reset_slot(s)
                self.slot_req[s] = req
                self.slot_pending[s] = list(req.prompt)

    def step(self) -> int:
        """One batched decode step across all slots; returns #active."""
        self._admit()
        tokens = np.zeros((self.num_slots, 1), np.int32)
        for s in range(self.num_slots):
            req = self.slot_req[s]
            if req is None:
                continue
            if self.slot_pending[s]:
                tokens[s, 0] = self.slot_pending[s].pop(0)
            elif req.out:
                tokens[s, 0] = req.out[-1]
            else:
                tokens[s, 0] = req.prompt[-1]
        nxt, self.state = self._step(
            self.params, self.state, jnp.asarray(tokens), None
        )
        nxt = np.asarray(nxt)
        active = 0
        for s in range(self.num_slots):
            req = self.slot_req[s]
            if req is None:
                continue
            active += 1
            if not self.slot_pending[s]:  # prompt fully injected -> emit
                req.out.append(int(nxt[s]))
                if req.done:
                    self.finished.append(req)
                    self.slot_req[s] = None
        return active

    def run(self, max_steps: int = 10_000) -> list[Request]:
        steps = 0
        while (self.queue or any(r is not None for r in self.slot_req)) and steps < max_steps:
            self.step()
            steps += 1
        return self.finished
