"""Continuous batching: slot-based request scheduling over a fixed batch.

Production serving keeps the decode batch full by admitting new requests
into slots as old ones finish — the decode step itself never recompiles
(static shapes).  Each slot carries its own cache position: the decode
step takes a [B] vector of per-slot positions, so a request admitted
mid-stream masks and writes at ITS OWN ring position starting from 0,
while older slots continue at their depths.  (The earlier reference
implementation shared one global counter across slots, which both wasted
cache capacity and clamped at ``cache_len``; per-slot positions remove
that limitation.)

This is the HiHGNN workload-balance idea at the serving layer: slots are
lanes, the admission queue is the overflow-workload list, and the
scheduler keeps every lane busy.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..models.lm.api import LMApi
from .engine import ServeState, init_serve_state


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int
    out: list[int] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.out) >= self.max_new


class ContinuousBatcher:
    """Fixed-slot continuous batcher (greedy decoding).

    Limitations of this reference implementation: prompts are injected by
    stepping them token-by-token through the slot (prefill == decode
    path), which is latency-suboptimal but keeps one compiled program;
    a production variant would add a separate batched prefill program.
    """

    def __init__(self, api: LMApi, num_slots: int, cache_len: int, params):
        self.api = api
        self.params = params
        self.num_slots = num_slots
        self.cache_len = cache_len
        # per-slot serving state: independent caches stacked on batch dim
        self.state = init_serve_state(api, num_slots, cache_len, dtype=jnp.float32)
        self.slot_req: list[Request | None] = [None] * num_slots
        self.slot_pos = np.zeros(num_slots, np.int32)  # per-slot cache position
        self.slot_pending: list[list[int]] = [[] for _ in range(num_slots)]
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self._step = self._build_step()

    def _build_step(self) -> Callable:
        cfg = self.api.cfg
        api = self.api

        def step(params, state: ServeState, tokens, slot_pos):
            # slot_pos [B]: every slot masks and writes at its own cache
            # position (models/lm decode paths broadcast scalar-or-vector)
            kw = {}
            if cfg.is_encoder_decoder:
                kw["cross_kv"] = state.cross_kv
            logits, caches = api.decode(params, tokens, slot_pos, state.caches, **kw)
            nxt = jnp.argmax(logits[:, 0, : cfg.vocab_size], axis=-1).astype(jnp.int32)
            new_state = ServeState(
                caches=caches, cache_pos=state.cache_pos + 1, cross_kv=state.cross_kv
            )
            return nxt, new_state

        return jax.jit(step)

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _reset_slot(self, s: int) -> None:
        """Invalidate slot s's cache rows so a newly admitted request never
        attends to the previous occupant (pos -1 == masked; states zeroed).
        The slot dim follows the init_caches layout: ``caches["scan"]``
        leaves are scan-stacked [n_super, B, ...] (slot dim 1),
        ``caches["tail"]`` leaves are [B, ...] (slot dim 0) — located by
        structure, not by size, so num_slots == n_layers stays correct."""

        def reset_at(dim: int):
            def reset(x):
                idx = (slice(None),) * dim + (s,)
                if jnp.issubdtype(x.dtype, jnp.integer):
                    return x.at[idx].set(-1)
                return x.at[idx].set(0)

            return reset

        caches = dict(self.state.caches)
        if "scan" in caches:
            caches["scan"] = jax.tree_util.tree_map(reset_at(1), caches["scan"])
        if "tail" in caches:
            caches["tail"] = jax.tree_util.tree_map(reset_at(0), caches["tail"])
        self.state = ServeState(
            caches=caches,
            cache_pos=self.state.cache_pos,
            cross_kv=self.state.cross_kv,
        )

    def _admit(self) -> None:
        for s in range(self.num_slots):
            if self.slot_req[s] is None and self.queue:
                req = self.queue.pop(0)
                self._reset_slot(s)
                self.slot_req[s] = req
                self.slot_pos[s] = 0  # fresh request starts at ITS position 0
                self.slot_pending[s] = list(req.prompt)

    def step(self) -> int:
        """One batched decode step across all slots; returns #active."""
        self._admit()
        tokens = np.zeros((self.num_slots, 1), np.int32)
        for s in range(self.num_slots):
            req = self.slot_req[s]
            if req is None:
                continue
            if self.slot_pending[s]:
                tokens[s, 0] = self.slot_pending[s].pop(0)
            elif req.out:
                tokens[s, 0] = req.out[-1]
            else:
                tokens[s, 0] = req.prompt[-1]
        nxt, self.state = self._step(
            self.params, self.state, jnp.asarray(tokens), jnp.asarray(self.slot_pos)
        )
        nxt = np.asarray(nxt)
        active = 0
        for s in range(self.num_slots):
            req = self.slot_req[s]
            if req is None:
                continue
            active += 1
            self.slot_pos[s] += 1
            if not self.slot_pending[s]:  # prompt fully injected -> emit
                req.out.append(int(nxt[s]))
                if req.done:
                    self.finished.append(req)
                    self.slot_req[s] = None
        return active

    def run(self, max_steps: int = 10_000) -> list[Request]:
        steps = 0
        while (self.queue or any(r is not None for r in self.slot_req)) and steps < max_steps:
            self.step()
            steps += 1
        return self.finished
