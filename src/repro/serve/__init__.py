from .engine import ServeState, make_prefill, make_serve_step, init_serve_state

__all__ = ["ServeState", "make_prefill", "make_serve_step", "init_serve_state"]
