from .engine import ServeState, make_prefill, make_serve_step, init_serve_state
from .fp_cache import FPCache, FPCacheStats
from .hgnn_engine import GraphRequest, HGNNEngine, make_request_mix

__all__ = [
    "ServeState",
    "make_prefill",
    "make_serve_step",
    "init_serve_state",
    "FPCache",
    "FPCacheStats",
    "GraphRequest",
    "HGNNEngine",
    "make_request_mix",
]
