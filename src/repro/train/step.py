"""Train-step builders for the LM architectures.

Production features:
  * microbatch gradient accumulation (scan) — grok/mamba2 activation fit
  * grads sharding-constrained to the parameter layout inside the scan
    (keeps the accumulator ZeRO-sharded instead of replicated)
  * vocab-padding masked out of the loss
  * MoE auxiliary load-balance loss folded in
  * fp32 loss/grad-norm metrics regardless of compute dtype
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..dist.sharding import active_rules
from ..models.lm.api import LMApi
from ..models.lm.transformer import vocab_padded
from ..optim import AdamWConfig, apply_updates, init_opt_state, opt_state_axes
from ..optim.schedules import warmup_cosine


@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Any
    step: jnp.ndarray


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.params, s.opt, s.step), None),
    lambda _, ch: TrainState(*ch),
)


def init_train_state(api: LMApi, rng: jax.Array, opt_cfg: AdamWConfig) -> TrainState:
    params = api.init(rng)
    return TrainState(params=params, opt=init_opt_state(params, opt_cfg), step=jnp.zeros((), jnp.int32))


def train_state_axes(api: LMApi, opt_cfg: AdamWConfig, params_abstract=None):
    pax = api.axes()
    return TrainState(
        params=pax,
        opt=opt_state_axes(pax, opt_cfg, params_abstract),
        step=(),
    )


def lm_loss(api: LMApi, params, batch: dict) -> tuple[jnp.ndarray, dict]:
    """Next-token CE with vocab padding masked; returns (loss, metrics)."""
    cfg = api.cfg
    tokens = batch["tokens"]  # [B, S+1]
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    kw = {}
    if "frames" in batch:
        kw["frames"] = batch["frames"]
    if "positions" in batch:
        kw["positions"] = batch["positions"]
    if "visual_embeds" in batch:
        kw["visual_embeds"] = batch["visual_embeds"]
    logits, aux = api.forward(params, inputs, **kw)
    vp = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    if vp > cfg.vocab_size:  # mask padded vocab slots out of the softmax
        pad_mask = jnp.arange(vp) >= cfg.vocab_size
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    loss = nll.mean()
    total = loss + 0.01 * aux
    return total, {"loss": loss, "aux_loss": aux}


def _shard_like_params(grads, param_axes):
    rules = active_rules()
    if rules is None:
        return grads
    is_axes = lambda a: isinstance(a, tuple) and all(isinstance(x, (str, type(None))) for x in a)
    return jax.tree_util.tree_map(
        lambda a, g: jax.lax.with_sharding_constraint(g, rules.spec(a)),
        param_axes, grads, is_leaf=is_axes,
    )


def make_train_step(
    api: LMApi,
    opt_cfg: AdamWConfig,
    *,
    microbatches: int = 1,
    lr_schedule: Callable[[jnp.ndarray], jnp.ndarray] | None = None,
    grad_dtype: str | None = None,
) -> Callable[[TrainState, dict], tuple[TrainState, dict]]:
    """Build the (pjit-able) train step.  batch leaves are [B_global, ...].

    ``grad_dtype="bfloat16"`` enables gradient compression: per-microbatch
    gradients are cast to bf16 *before* the cross-shard reduction the SPMD
    partitioner inserts, halving gradient-sync ICI bytes; the accumulator
    stays fp32 (compression applies to the wire format only).
    """
    param_axes = api.axes()
    sched = lr_schedule or (lambda s: warmup_cosine(s, peak_lr=opt_cfg.lr))
    gdt = jnp.dtype(grad_dtype) if grad_dtype else None

    def loss_fn(params, mb):
        return lm_loss(api, params, mb)

    def train_step(state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                state.params, batch
            )
            if gdt is not None:  # gradient compression on the wire
                grads = jax.tree_util.tree_map(lambda x: x.astype(gdt), grads)
            grads = _shard_like_params(grads, param_axes)
        else:
            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0, (b, microbatches)
                return x.reshape((microbatches, b // microbatches) + x.shape[1:])

            mbs = jax.tree_util.tree_map(split, batch)
            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )
            zero_g = _shard_like_params(zero_g, param_axes)

            def acc(carry, mb):
                g_acc, l_acc, a_acc = carry
                (l, mx), g = jax.value_and_grad(loss_fn, has_aux=True)(state.params, mb)
                if gdt is not None:  # gradient compression on the wire
                    g = jax.tree_util.tree_map(lambda x: x.astype(gdt), g)
                # constrain only the accumulator: the per-microbatch grad is
                # then free to be reduce-scattered directly into the carry
                # layout (§Perf HC3 — double-constraining forced an extra
                # replicated all-reduce per microbatch)
                g_acc = jax.tree_util.tree_map(
                    lambda x, y: x + y.astype(jnp.float32), g_acc, g
                )
                g_acc = _shard_like_params(g_acc, param_axes)
                return (g_acc, l_acc + mx["loss"], a_acc + mx["aux_loss"]), None

            (grads, loss_sum, aux_sum), _ = jax.lax.scan(
                acc, (zero_g, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), mbs
            )
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
            loss = loss_sum / microbatches
            metrics = {"loss": loss, "aux_loss": aux_sum / microbatches}

        lr = sched(state.step)
        new_params, new_opt, gnorm = apply_updates(
            state.params, grads, state.opt, opt_cfg, lr
        )
        metrics = dict(metrics, grad_norm=gnorm, lr=lr)
        return TrainState(params=new_params, opt=new_opt, step=state.step + 1), metrics

    return train_step
