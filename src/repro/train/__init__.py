from .step import TrainState, make_train_step, lm_loss, train_state_axes
from .loop import train_loop

__all__ = ["TrainState", "make_train_step", "lm_loss", "train_state_axes", "train_loop"]
