from .step import TrainState, make_train_step, lm_loss, train_state_axes
from .loop import train_loop
from .hgnn import (
    hgnn_param_axes,
    hgnn_train_state_axes,
    init_hgnn_train_state,
    make_hgnn_train_step,
)

__all__ = [
    "TrainState",
    "make_train_step",
    "lm_loss",
    "train_state_axes",
    "train_loop",
    "hgnn_param_axes",
    "hgnn_train_state_axes",
    "init_hgnn_train_state",
    "make_hgnn_train_step",
]
