"""Train-step builder for the HGNN models (HAN, R-GAT, ...).

The HGNN trainer reuses the LM substrate wholesale: the generic
:class:`~repro.train.step.TrainState` (params/opt/step), the AdamW
optimizer, and the fault-tolerant ``train_loop`` — only the loss changes.
HGNNs here train transductively: the forward runs over the whole resident
graph every step (the semantic-graph batches are closed over as device
constants, like the serving engine holds them resident), and the step's
minibatch is a counter-based set of labeled target vertices
(data/pipeline.py:SyntheticHGNNData) whose cross-entropy is optimized.

``make_hgnn_train_step`` takes the *forward function*, not the model: the
mesh-scale launcher passes ``han_forward_multilane`` closed over a
MultiLanePlan + lane mesh (NA through the fused multigraph kernel per
lane shard, DESIGN.md §11); tests pass plain ``model.forward`` with any
NABackend.  Both produce the identical train step because every NA
backend and lane count is numerically equivalent (the backend-equivalence
contract, tests/test_multilane).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..models.hgnn.common import HGNNData, HGNNModel
from ..optim import AdamWConfig, apply_updates, init_opt_state, opt_state_axes
from .step import TrainState

# Logical parameter axes by leaf name (model code stays mesh-free; the
# lanes rules map "mlp"/"heads" onto the model axis and replicate the
# rest across lanes — every lane gathers from the full projected table,
# the functional RAB).  Unknown names replicate, so new params are safe.
_HGNN_PARAM_AXES: dict[str, tuple[str | None, ...]] = {
    "w_fp": ("embed", "mlp"),
    "b_fp": ("mlp",),
    "a_src": ("act_graph", "heads", None),
    "a_dst": ("act_graph", "heads", None),
    "w_src": ("embed", "mlp"),
    "w_dst": ("embed", "mlp"),
    "w_g": ("mlp", None),
    "w_out": ("mlp", None),
}


def hgnn_param_axes(params) -> Any:
    """Logical-axes pytree for an HGNN params tree (same structure).

    Leaves are keyed by their last tree-path component; anything not in
    the table replicates (``(None,) * ndim``).
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    axes = []
    for path, leaf in flat:
        name = str(getattr(path[-1], "key", path[-1]))
        ax = _HGNN_PARAM_AXES.get(name)
        if ax is None or len(ax) != leaf.ndim:
            ax = (None,) * leaf.ndim
        axes.append(tuple(ax))
    return jax.tree_util.tree_unflatten(treedef, axes)


def init_hgnn_train_state(
    model: HGNNModel, rng: jax.Array, data: HGNNData, opt_cfg: AdamWConfig, **init_kw
) -> TrainState:
    params = model.init(rng, data, **init_kw)
    return TrainState(
        params=params, opt=init_opt_state(params, opt_cfg), step=jnp.zeros((), jnp.int32)
    )


def hgnn_train_state_axes(state: TrainState, opt_cfg: AdamWConfig) -> TrainState:
    """Logical-axes TrainState for ``dist.param_shardings`` (elastic
    restarts re-derive shardings from THIS, against whatever lane mesh the
    new run has — checkpoint bits are mesh-free)."""
    pax = hgnn_param_axes(state.params)
    return TrainState(params=pax, opt=opt_state_axes(pax, opt_cfg, state.params), step=())


def make_hgnn_train_step(
    forward_fn: Callable[[Any], jnp.ndarray],
    data: HGNNData,
    opt_cfg: AdamWConfig,
    *,
    lr_schedule: Callable[[jnp.ndarray], jnp.ndarray] | None = None,
) -> Callable[[TrainState, dict], tuple[TrainState, dict]]:
    """Build the (jit-able) HGNN train step.

    ``forward_fn(params) -> logits [N_target, C]`` runs the full-graph
    forward; ``batch["idx"]`` selects the step's labeled minibatch.
    Metrics carry ``loss``/``grad_norm`` (the train_loop contract) plus
    minibatch accuracy.
    """
    assert data.labels is not None, "training needs labels in HGNNData"
    sched = lr_schedule or (lambda s: jnp.asarray(opt_cfg.lr))

    def loss_fn(params, idx):
        logits = forward_fn(params)
        lp = jax.nn.log_softmax(logits[idx].astype(jnp.float32), axis=-1)
        y = data.labels[idx]
        loss = -jnp.take_along_axis(lp, y[:, None], axis=-1)[:, 0].mean()
        acc = (jnp.argmax(lp, axis=-1) == y).mean()
        return loss, {"loss": loss, "acc": acc}

    def train_step(state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state.params, batch["idx"]
        )
        lr = sched(state.step)
        new_params, new_opt, gnorm = apply_updates(
            state.params, grads, state.opt, opt_cfg, lr
        )
        metrics = dict(metrics, grad_norm=gnorm, lr=lr)
        return TrainState(params=new_params, opt=new_opt, step=state.step + 1), metrics

    return train_step
