"""Fault-tolerant training loop: checkpoint/restart with exact replay.

The loop owns nothing it cannot reconstruct: model state comes from the
latest checkpoint (atomic manifest dirs), data comes from a counter-based
pipeline whose state rides in the checkpoint aux — so a crash at any step
resumes bit-identically (tests/test_train::test_crash_resume).  On a real
cluster this loop runs per-host under a supervisor that re-launches failed
workers; elastic restarts go through checkpoint.reshard_to with the new
mesh (straggler posture: synchronous steps + restart-on-failure, DESIGN §4).
"""
from __future__ import annotations

import time
from typing import Callable

import jax
import numpy as np

from ..checkpoint import latest_step, restore_checkpoint, save_checkpoint
from ..data.pipeline import SyntheticLMData
from ..obs.emit import Emitter
from ..obs.metrics import MetricsRegistry, get_registry
from ..obs.trace import trace_span
from .step import TrainState


def train_loop(
    *,
    state: TrainState,
    train_step: Callable,
    data: SyntheticLMData,
    steps: int,
    ckpt_dir: str | None = None,
    ckpt_every: int = 50,
    resume: bool = True,
    crash_at: int | None = None,  # fault-injection hook for tests
    log_every: int = 10,
    log: Callable[[str], None] = print,
    log_jsonl: str | None = None,  # mirror structured records to a JSONL file
    registry: MetricsRegistry | None = None,
    state_shardings=None,  # elastic restart: place restored leaves on THIS mesh
) -> tuple[TrainState, list[dict]]:
    """Run ``steps`` train steps with checkpointing and structured logging.

    Observability (DESIGN.md §12): every step increments ``train.steps``
    and lands its wall time in the ``train.step_ms`` histogram; logged
    steps additionally set the ``train.loss``/``train.grad_norm`` gauges
    and emit a structured ``[train] step=… loss=… sec=…`` record through
    :class:`Emitter` (``log=`` stays the injectable sink).  Per-step
    ``sec`` on logged steps includes the device sync the host-side metric
    conversion forces; between log points it is dispatch wall time —
    enable tracing (sync spans) for honest per-step device timing.
    """
    reg = registry if registry is not None else get_registry()
    em = Emitter(sink=log, jsonl_path=log_jsonl)
    step_ms = reg.histogram("train.step_ms")
    steps_c = reg.counter("train.steps")

    start = 0
    if ckpt_dir and resume:
        last = latest_step(ckpt_dir)
        if last is not None:
            # state_shardings belongs to the CURRENT run's mesh, which may
            # differ from the mesh that wrote the checkpoint (elastic lane
            # restart) — the leaves on disk are logical arrays either way.
            state, aux = restore_checkpoint(
                ckpt_dir, last, state, shardings=state_shardings
            )
            data.restore(aux["data"])
            start = last
            em.emit("resume", step=last)

    history: list[dict] = []
    jitted = jax.jit(train_step)
    try:
        for step in range(start, steps):
            if crash_at is not None and step == crash_at:
                raise RuntimeError(f"injected failure at step {step}")
            t0 = time.perf_counter()
            batch = data.next()
            with trace_span("train/step", step=step) as sp:
                state, metrics = jitted(state, batch)
                sp.sync(metrics)
            dt = time.perf_counter() - t0
            if step % log_every == 0 or step == steps - 1:
                m = {k: float(np.asarray(v)) for k, v in metrics.items()}
                m["step"] = step
                dt = m["sec"] = time.perf_counter() - t0  # includes the sync above
                history.append(m)
                reg.gauge("train.loss").set(m["loss"])
                reg.gauge("train.grad_norm").set(m["grad_norm"])
                em.emit(
                    "train",
                    step=step,
                    loss=m["loss"],
                    gnorm=m["grad_norm"],
                    sec=dt,
                )
            step_ms.observe(dt * 1e3)
            steps_c.inc()
            if ckpt_dir and (step + 1) % ckpt_every == 0:
                save_checkpoint(ckpt_dir, step + 1, state, aux={"data": data.state()})
        if ckpt_dir:
            save_checkpoint(ckpt_dir, steps, state, aux={"data": data.state()})
    finally:
        em.close()
    return state, history
