"""Fault-tolerant training loop: checkpoint/restart with exact replay.

The loop owns nothing it cannot reconstruct: model state comes from the
latest checkpoint (atomic manifest dirs), data comes from a counter-based
pipeline whose state rides in the checkpoint aux — so a crash at any step
resumes bit-identically (tests/test_train::test_crash_resume).  On a real
cluster this loop runs per-host under a supervisor that re-launches failed
workers; elastic restarts go through checkpoint.reshard_to with the new
mesh (straggler posture: synchronous steps + restart-on-failure, DESIGN §4).
"""
from __future__ import annotations

import time
from typing import Callable

import jax
import numpy as np

from ..checkpoint import latest_step, restore_checkpoint, save_checkpoint
from ..data.pipeline import SyntheticLMData
from .step import TrainState


def train_loop(
    *,
    state: TrainState,
    train_step: Callable,
    data: SyntheticLMData,
    steps: int,
    ckpt_dir: str | None = None,
    ckpt_every: int = 50,
    resume: bool = True,
    crash_at: int | None = None,  # fault-injection hook for tests
    log_every: int = 10,
    log: Callable[[str], None] = print,
    state_shardings=None,  # elastic restart: place restored leaves on THIS mesh
) -> tuple[TrainState, list[dict]]:
    start = 0
    if ckpt_dir and resume:
        last = latest_step(ckpt_dir)
        if last is not None:
            # state_shardings belongs to the CURRENT run's mesh, which may
            # differ from the mesh that wrote the checkpoint (elastic lane
            # restart) — the leaves on disk are logical arrays either way.
            state, aux = restore_checkpoint(
                ckpt_dir, last, state, shardings=state_shardings
            )
            data.restore(aux["data"])
            start = last
            log(f"[resume] restored step {last}")

    history: list[dict] = []
    jitted = jax.jit(train_step)
    for step in range(start, steps):
        if crash_at is not None and step == crash_at:
            raise RuntimeError(f"injected failure at step {step}")
        t0 = time.perf_counter()
        batch = data.next()
        state, metrics = jitted(state, batch)
        if step % log_every == 0 or step == steps - 1:
            m = {k: float(np.asarray(v)) for k, v in metrics.items()}
            m["step"] = step
            m["sec"] = time.perf_counter() - t0
            history.append(m)
            log(f"[train] step={step} loss={m['loss']:.4f} gnorm={m['grad_norm']:.3f}")
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            save_checkpoint(ckpt_dir, step + 1, state, aux={"data": data.state()})
    if ckpt_dir:
        save_checkpoint(ckpt_dir, steps, state, aux={"data": data.state()})
    return state, history
