import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# Production-scale dry-run of the paper's OWN technique: multi-lane HGNN
# NA+GSF with lanes sharded over a dedicated `lane` mesh axis (one lane
# group per chip column — the accelerator's scale-up §4.2 mapped onto a
# pod).  Layout comes from the "lanes" sharding rules (DESIGN.md §5),
# consumed exactly the way the LM launch path consumes its rules.

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core.fusion import FusedFPInputs
from ..core.multilane import MultiLanePlan, multilane_na, multilane_na_sharded
from ..core.scheduling import LanePlan
from ..core import stages
from ..dist.sharding import make_rules, use_rules
from ..obs import disable_tracing, enable_tracing, trace_span
from .hlostats import analyze, span_attrs
from .mesh import make_lane_mesh

PEAK_FLOPS = 197e12
ICI_BW = 50e9


def abstract_plan(lanes: int, units: int, w: int, block: int, graphs: int, rows: int):
    dummy = LanePlan(
        unit_graph=np.zeros(1, np.int32), unit_row=np.zeros(1, np.int32),
        unit_cost=np.zeros(1), unit_lane=np.zeros(1, np.int32), lane_load=np.ones(lanes),
    )
    return MultiLanePlan(
        col_index=jax.ShapeDtypeStruct((lanes, units, w), jnp.int32),
        masks=jax.ShapeDtypeStruct((lanes, units, w, block, block), jnp.bool_),
        graph_id=jax.ShapeDtypeStruct((lanes, units), jnp.int32),
        dst_row=jax.ShapeDtypeStruct((lanes, units), jnp.int32),
        valid=jax.ShapeDtypeStruct((lanes, units), jnp.bool_),
        block=block,
        num_graphs=graphs,
        n_dst_blocks=rows,
        lane_plan=dummy,
    )


def aligned_lane_step_builder(g, rows_per_lane, block, h_dim, dh, ns_pad):
    """Beyond-paper scheduling (§Perf HC-paper): co-locate the SAME dst
    rows of all semantic graphs on one lane.  The GSF combine across
    graphs becomes lane-LOCAL (a reshape, not the paper's crossbar
    transfer); only the LSF scalars cross lanes (psum of [G])."""

    def unit_row(cols, mrow, row_idx, th_s, th_d, h_src, bias):
        # cols [G, W], mrow [G, W, B, B] — all graphs of one dst row
        def per_graph(c, m, gi):
            from ..core.multilane import _unit_na

            return _unit_na(c, m, gi, row_idx, th_s, th_d, h_src, bias, 0.2)

        return jax.vmap(per_graph)(cols, mrow, jnp.arange(g))  # [G, B, H, Dh]

    def lane_step(col_index, masks, row_ids, th_s, th_d, h_src, w_g, q):
        bias = jnp.zeros((g, h_dim), jnp.float32)
        hs = h_src.astype(jnp.float32)
        z = jax.vmap(jax.vmap(unit_row, in_axes=(0, 0, 0, None, None, None, None)),
                     in_axes=(0, 0, 0, None, None, None, None))(
            col_index, masks, row_ids, th_s, th_d, hs, bias
        )  # [L, U_r, G, B, H, Dh]
        lanes, ur = z.shape[0], z.shape[1]
        zf = z.reshape(lanes, ur, g, block, h_dim * dh)
        # LSF: per-lane partial semantic importances; psum is implicit in
        # the global mean over the lane-sharded axis
        s = jnp.tanh(zf @ w_g) @ q  # [L, U_r, G, B]
        w_p = s.mean(axis=(0, 1, 3)) * (lanes * ur * block) / ns_pad  # [G]
        beta = jax.nn.softmax(w_p)
        fused = jnp.einsum("g,lugbd->lubd", beta, zf)  # lane-local GSF
        return fused, beta

    return lane_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--vertices", type=int, default=1_048_576)
    ap.add_argument("--graphs", type=int, default=3)
    ap.add_argument("--heads", type=int, default=8)
    ap.add_argument("--dh", type=int, default=64)
    ap.add_argument("--width", type=int, default=16, help="blocks per row")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--schedule", choices=("balanced", "aligned"), default="balanced")
    ap.add_argument(
        "--executor", choices=("spmd", "shard_map"), default="spmd",
        help="balanced schedule only: partitioner-placed (jit in_shardings) "
        "or explicit shard_map over the lane axis",
    )
    ap.add_argument(
        "--na-backend",
        choices=("reference", "kernel", "kernel_interpret", "fused_fp", "fused_fp_interpret"),
        default="reference",
        help="balanced schedule only: per-unit NA executor for multilane_na "
        "('kernel' = one fused Pallas launch per chip; needs TPU lowering, "
        "'kernel_interpret' validates the same kernel on CPU; 'fused_fp' = "
        "the FP+NA stage-fusion megakernel streaming RAW features, "
        "'fused_fp_interpret' its CPU validator)",
    )
    ap.add_argument(
        "--din", type=int, default=256,
        help="fused_fp backends only: raw feature width streamed into the megakernel",
    )
    ap.add_argument("--out", default="artifacts/dryrun/hgnn_multilane.json")
    ap.add_argument(
        "--trace", default=None, metavar="PATH",
        help="write a Chrome-trace JSON of the dry-run (lower/compile spans; "
             "the compiled program's span carries hlostats collective-bytes "
             "and dot-FLOP attributes)",
    )
    args = ap.parse_args()
    tracer = enable_tracing(sync=True) if args.trace else None
    if args.schedule == "aligned" and args.executor != "spmd":
        ap.error("--executor shard_map only applies to --schedule balanced")
    if args.schedule == "aligned" and args.na_backend != "reference":
        ap.error("--na-backend only applies to --schedule balanced")

    block = 128
    rows = args.vertices // block
    mesh = make_lane_mesh(multi_pod=args.multi_pod)
    rules = make_rules(multi_pod=args.multi_pod, parallelism="lanes")
    lanes = 32 * 16 if args.multi_pod else 16 * 16  # one lane per chip
    units = rows * args.graphs // lanes
    g, h_dim, dh = args.graphs, args.heads, args.dh

    plan = abstract_plan(lanes, units, args.width, block, g, rows)
    ns_pad = rows * block
    th_s = jax.ShapeDtypeStruct((g, ns_pad, h_dim), jnp.float32)
    th_d = jax.ShapeDtypeStruct((g, rows * block, h_dim), jnp.float32)
    h_src = jax.ShapeDtypeStruct((ns_pad, h_dim, dh), jnp.bfloat16)
    # HAN semantic-attention params (LSF/GSF fused after NA)
    w_g = jax.ShapeDtypeStruct((h_dim * dh, 128), jnp.float32)
    q = jax.ShapeDtypeStruct((128,), jnp.float32)

    lane_axis = rules.mesh_axes("act_lane")

    def _sf_tail(z, w_g, q):
        zf = z.reshape(g, ns_pad, h_dim * dh)
        valid = jnp.ones((ns_pad,), bool)
        w_p = jnp.stack([
            stages.local_semantic_fusion(zf[p], w_g, jnp.zeros((128,)), q, valid)
            for p in range(g)
        ])
        fused, beta = stages.global_semantic_fusion(w_p, zf)
        return fused, beta

    def lane_step(plan, th_s, th_d, h_src, w_g, q):
        na = (
            (lambda p, a, b, c: multilane_na_sharded(
                p, a, b, c, mesh=mesh, lane_axes=lane_axis, backend=args.na_backend))
            if args.executor == "shard_map"
            else (lambda p, a, b, c: multilane_na(p, a, b, c, backend=args.na_backend))
        )
        z = na(plan, th_s, th_d, h_src.astype(jnp.float32))  # [G, N, H, Dh]
        return _sf_tail(z, w_g, q)

    def lane_step_fp(plan, fp, w_g, q):
        # Megakernel path: theta/h' never exist as program inputs — the
        # kernel streams RAW features and projects on-chip (DESIGN.md §10).
        if args.executor == "shard_map":
            z = multilane_na_sharded(
                plan, None, None, None,
                mesh=mesh, lane_axes=lane_axis, backend=args.na_backend, fp=fp,
            )
        else:
            z = multilane_na(plan, None, None, None, backend=args.na_backend, fp=fp)
        return _sf_tail(z, w_g, q)

    lane_sh = lambda *rest: NamedSharding(mesh, rules.spec(("act_lane",) + rest))
    feat_sh = NamedSharding(mesh, rules.spec((None, None, "act_feat")))
    rep = NamedSharding(mesh, P())
    with mesh, use_rules(rules):
        if args.schedule == "aligned":
            u_r = rows // lanes
            col_abs = jax.ShapeDtypeStruct((lanes, u_r, g, args.width), jnp.int32)
            mask_abs = jax.ShapeDtypeStruct((lanes, u_r, g, args.width, block, block), jnp.bool_)
            rowid_abs = jax.ShapeDtypeStruct((lanes, u_r), jnp.int32)
            step = aligned_lane_step_builder(g, u_r, block, h_dim, dh, ns_pad)
            lowered = jax.jit(
                step,
                in_shardings=(
                    lane_sh(None, None, None), lane_sh(None, None, None, None, None),
                    lane_sh(None), rep, rep,
                    feat_sh, rep, rep,
                ),
            ).lower(col_abs, mask_abs, rowid_abs, th_s, th_d, h_src, w_g, q)
            units = u_r
        else:
            plan_sh = MultiLanePlan(
                col_index=lane_sh(None, None),
                masks=lane_sh(None, None, None, None),
                graph_id=lane_sh(None),
                dst_row=lane_sh(None),
                valid=lane_sh(None),
                block=block, num_graphs=g, n_dst_blocks=rows, lane_plan=plan.lane_plan,
            )
            if args.na_backend.startswith("fused_fp"):
                fp_abs = FusedFPInputs(
                    x=jax.ShapeDtypeStruct((ns_pad, args.din), jnp.float32),
                    w=jax.ShapeDtypeStruct((1, args.din, h_dim * dh), jnp.float32),
                    b=jax.ShapeDtypeStruct((1, h_dim * dh), jnp.float32),
                    a_src=jax.ShapeDtypeStruct((g, h_dim, dh), jnp.float32),
                    a_dst=jax.ShapeDtypeStruct((g, h_dim, dh), jnp.float32),
                    wsel=jax.ShapeDtypeStruct((g,), jnp.int32),
                )
                x_sh = NamedSharding(mesh, rules.spec((None, "act_feat")))
                fp_sh = FusedFPInputs(x=x_sh, w=rep, b=rep, a_src=rep, a_dst=rep, wsel=rep)
                lowered = jax.jit(
                    lane_step_fp,
                    in_shardings=(plan_sh, fp_sh, rep, rep),
                ).lower(plan, fp_abs, w_g, q)
            else:
                lowered = jax.jit(
                    lane_step,
                    in_shardings=(plan_sh, rep, rep, feat_sh, rep, rep),
                ).lower(plan, th_s, th_d, h_src, w_g, q)
        try:
            with trace_span(
                "dryrun/compile", stage="compile", schedule=args.schedule,
                executor=args.executor, backend=args.na_backend, lanes=lanes,
            ):
                compiled = lowered.compile()
        except Exception as e:
            if args.na_backend in ("kernel", "fused_fp") and jax.default_backend() != "tpu":
                raise SystemExit(
                    f"--na-backend {args.na_backend} needs a TPU to compile the "
                    f"Pallas kernel (host backend: {jax.default_backend()}); "
                    f"use --na-backend {args.na_backend}_interpret to validate "
                    f"on this host.  Compile error: {e}"
                ) from e
            raise
    mem = compiled.memory_analysis()
    with trace_span("dryrun/hlostats", stage="analyze") as sp:
        stats = analyze(compiled.as_text())
        # the compiled program's communication/compute footprint rides on
        # its span in the exported timeline
        sp.annotate(**span_attrs(stats, schedule=args.schedule))
    edges_equiv = lanes * units * args.width * block * block  # masked-dense positions
    flops = stats.dot_flops
    result = dict(
        status="ok",
        schedule=args.schedule,
        executor=args.executor,
        mesh="pod2x16x16" if args.multi_pod else "pod16x16",
        lanes=lanes, units_per_lane=units, vertices=args.vertices, graphs=g,
        mem_per_device_gib=(mem.argument_size_in_bytes + mem.temp_size_in_bytes
                            + mem.output_size_in_bytes - mem.alias_size_in_bytes) / 2**30,
        dot_flops_per_device=flops,
        collective_bytes=stats.collective_bytes,
        compute_s=flops / PEAK_FLOPS,
        collective_s=sum(stats.collective_bytes.values()) / ICI_BW,
        dense_block_positions=edges_equiv,
    )
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    print(json.dumps(result, indent=1))
    if tracer is not None:
        tracer.export_chrome_trace(args.trace)
        disable_tracing()
        print(f"wrote {args.trace}")


if __name__ == "__main__":
    main()
