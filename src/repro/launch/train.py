"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --smoke --steps 20

On real hardware: builds the production mesh, applies the logical-axis
sharding rules, and runs the fault-tolerant loop with sharded state.  On
this CPU container, --smoke runs the reduced config on a 1×1 mesh —
exactly the same code path (mesh, rules, jit-with-shardings) at toy size;
the full configs are exercised by launch/dryrun.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from ..configs import ARCH_IDS, get_config, smoke_config
from ..data import SyntheticLMData
from ..dist.sharding import make_rules, param_shardings, use_rules
from ..models.lm.api import build
from ..optim import AdamWConfig
from ..train import make_train_step, train_loop
from ..train.step import init_train_state, train_state_axes
from .mesh import make_mesh, make_production_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    api = build(cfg)
    opt = AdamWConfig(lr=1e-2 if args.smoke else 3e-4, weight_decay=0.0 if args.smoke else 0.1)

    n_dev = len(jax.devices())
    if args.smoke or n_dev < 256:
        mesh = make_mesh((1, 1), ("data", "model")) if n_dev == 1 else make_mesh(
            (n_dev, 1), ("data", "model")
        )
        rules = make_rules(batch_shard=n_dev > 1, fsdp=False)
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        rules = make_rules(multi_pod=args.multi_pod, fsdp=cfg.fsdp)

    data = SyntheticLMData(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.global_batch,
        seed=0, with_frames=cfg.frontend == "audio",
        frame_len=cfg.encoder_seq, d_model=cfg.d_model,
    )
    with mesh, use_rules(rules):
        state = init_train_state(api, jax.random.key(0), opt)
        axes = train_state_axes(api, opt, state.params)
        state_sh = param_shardings(mesh, rules, axes)
        state = jax.device_put(state, state_sh)
        step = make_train_step(
            api, opt, microbatches=args.microbatches,
            lr_schedule=(lambda s: jnp.asarray(1e-2)) if args.smoke else None,
        )
        state, hist = train_loop(
            state=state, train_step=step, data=data, steps=args.steps,
            ckpt_dir=args.ckpt, log_every=5,
        )
    print(f"final loss {hist[-1]['loss']:.4f} (start {hist[0]['loss']:.4f})")


if __name__ == "__main__":
    main()
