"""Production serving launcher: batched prefill + decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --smoke

Same engine the decode_* dry-run cells lower; --smoke executes the
reduced config on CPU.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCH_IDS, get_config, smoke_config
from ..models.lm.api import build
from ..serve.engine import greedy_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--steps", type=int, default=16)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    api = build(cfg)
    params = api.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32
    )
    t0 = time.time()
    out = greedy_generate(
        api, params, prompts, steps=args.steps,
        cache_len=args.prompt_len + args.steps + 1,
    )
    dt = time.time() - t0
    print(f"{cfg.name}: {args.batch * args.steps} tokens in {dt:.2f}s")
    print(np.asarray(out))


if __name__ == "__main__":
    main()
