"""Production mesh builders (functions, never module-level constants —
importing this module must not touch jax device state)."""
from __future__ import annotations

import math

import jax


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Mesh over the first prod(shape) available devices."""
    n = math.prod(shape)
    devs = jax.devices()
    assert len(devs) >= n, f"need {n} devices, have {len(devs)}"
    return jax.make_mesh(
        shape,
        axes,
        devices=devs[:n],
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
    )


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod; multi-pod adds a leading 2-pod axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)
