"""Production mesh builders (functions, never module-level constants —
importing this module must not touch jax device state)."""
from __future__ import annotations

import math

import jax


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Mesh over the first prod(shape) available devices."""
    n = math.prod(shape)
    devs = jax.devices()
    assert len(devs) >= n, f"need {n} devices, have {len(devs)}"
    axis_type = getattr(jax.sharding, "AxisType", None)
    kw = {} if axis_type is None else {"axis_types": (axis_type.Auto,) * len(axes)}
    return jax.make_mesh(shape, axes, devices=devs[:n], **kw)


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod; multi-pod adds a leading 2-pod axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_lane_mesh(
    lanes: int | None = None,
    model: int | None = None,
    *,
    multi_pod: bool = False,
):
    """Mesh with a dedicated ``lane`` axis for multi-lane NA (paper §4.2).

    The lane axis carries (semantic graph, dst-block row) work units —
    ``core/multilane.py:multilane_na_sharded`` shard_maps over it — and
    the ``model`` axis carries head/feature dims.  With no sizes given,
    builds the production geometry: 16 lane groups × 16 model chips per
    pod (a leading 2-pod axis when ``multi_pod``).  Explicit sizes serve
    tests and CPU smoke runs (``make_lane_mesh(1, 1)`` on one device).
    """
    if lanes is None and model is None:
        shape = (2, 16, 16) if multi_pod else (16, 16)
    else:
        shape = ((2,) if multi_pod else ()) + (lanes or 1, model or 1)
    axes = ("pod", "lane", "model") if multi_pod else ("lane", "model")
    return make_mesh(shape, axes)
