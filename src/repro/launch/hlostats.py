"""Post-SPMD HLO analysis: loop-corrected collective & dot-FLOP accounting.

XLA's HloCostAnalysis visits a while body once (verified empirically in
EXPERIMENTS.md §Dry-run notes), so scanned-layer programs under-report by
~num_layers.  This parser walks the optimized HLO module text, recovers
while trip counts from their condition computations, propagates a
multiplier down the call graph (while/fusion/call), and accumulates:

  * collective result-bytes per op kind (all-reduce, all-gather,
    reduce-scatter, all-to-all, collective-permute, incl. -start forms)
  * dot FLOPs (2 · result_elems · contracted_size)

Both are *per-device* quantities in SPMD modules: shapes in the
partitioned module are already per-partition.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict


def normalize_cost_analysis(cost) -> dict:
    """`Compiled.cost_analysis()` returns a dict on current jax and a
    one-element list of dicts on older releases; hand back the dict."""
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|\w+\[[\d,]*\]\S*)\s+([\w\-]+)\("
)
_CALL_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_WHILE_ATTR_RE = re.compile(r"(body|condition)=%?([\w.\-]+)")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    dims = m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    type_str: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: list[Op]
    is_entry: bool


def parse_module(hlo_text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        header = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*(\([^{]*\))?\s*->.*\{", stripped)
        if header and not stripped.startswith("//") and "=" not in stripped.split("(")[0]:
            cur = Computation(name=header.group(2), ops=[], is_entry=bool(header.group(1)))
            comps[cur.name] = cur
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if m:
            cur.ops.append(Op(name=m.group(1), kind=m.group(3), type_str=m.group(2), line=line))
    return comps


def _while_trip_count(cond: Computation) -> int:
    """Canonical lowered loops compare the induction var with a constant."""
    consts = []
    for op in cond.ops:
        if op.kind == "constant":
            mm = re.search(r"constant\((-?\d+)\)", op.line)
            if mm:
                consts.append(int(mm.group(1)))
    pos = [c for c in consts if 0 < c <= 10_000_000]
    return max(pos) if pos else 1


def _multipliers(comps: dict[str, Computation]) -> dict[str, float]:
    entry = next((c for c in comps.values() if c.is_entry), None)
    mult: dict[str, float] = defaultdict(float)
    if entry is None:
        return {name: 1.0 for name in comps}
    mult[entry.name] = 1.0
    stack = [entry.name]
    seen_edges = set()
    while stack:
        name = stack.pop()
        comp = comps.get(name)
        if comp is None:
            continue
        m = mult[name]
        for op in comp.ops:
            if op.kind == "while":
                attrs = dict(_WHILE_ATTR_RE.findall(op.line))
                cond_name = attrs.get("condition")
                body_name = attrs.get("body")
                trip = _while_trip_count(comps[cond_name]) if cond_name in comps else 1
                for child in (cond_name, body_name):
                    if child and (name, child) not in seen_edges:
                        seen_edges.add((name, child))
                        mult[child] += m * trip
                        stack.append(child)
            else:
                for child in _CALL_RE.findall(op.line):
                    if child in comps and (name, child, op.name) not in seen_edges:
                        seen_edges.add((name, child, op.name))
                        mult[child] += m
                        stack.append(child)
    return dict(mult)


def _dot_flops(op: Op, symbols: dict[str, str]) -> float:
    """2 * result_elems * contracted_size (per partition).

    Operands are printed by name only in optimized HLO; their types come
    from the computation's symbol table (parameters + prior ops)."""
    res_elems = _shape_elems(op.type_str)
    call = op.line.split(op.kind + "(", 1)[-1]
    mops = re.match(r"\s*%?([\w.\-]+)", call)
    lhs_dims: list[int] = []
    if mops and mops.group(1) in symbols:
        sh = _SHAPE_RE.search(symbols[mops.group(1)])
        if sh and sh.group(2):
            lhs_dims = [int(d) for d in sh.group(2).split(",")]
    else:  # fall back to inline-typed operand, if present
        sh = _SHAPE_RE.search(call)
        if sh and sh.group(2):
            lhs_dims = [int(d) for d in sh.group(2).split(",")]
    mdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    contracted = 1
    if mdims and mdims.group(1):
        for d in mdims.group(1).split(","):
            contracted *= lhs_dims[int(d)] if int(d) < len(lhs_dims) else 1
    return 2.0 * res_elems * contracted


@dataclasses.dataclass
class HLOStats:
    collective_bytes: dict[str, float]       # kind -> loop-corrected bytes/device
    collective_bytes_static: dict[str, float]  # without loop correction
    collective_count: dict[str, int]
    dot_flops: float                          # loop-corrected, per device
    dot_flops_static: float
    while_trips: list[int]

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def analyze(hlo_text: str) -> HLOStats:
    comps = parse_module(hlo_text)
    mult = _multipliers(comps)
    coll: dict[str, float] = defaultdict(float)
    coll_static: dict[str, float] = defaultdict(float)
    count: dict[str, int] = defaultdict(int)
    dflops = 0.0
    dflops_static = 0.0
    trips = []
    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m == 0.0:
            continue  # unreachable (dead computation)
        symbols = {op.name: op.type_str for op in comp.ops}
        for op in comp.ops:
            kind = op.kind
            base = kind.replace("-start", "")
            if kind.endswith("-done"):
                continue
            if base in _COLLECTIVES:
                b = _shape_bytes(op.type_str)
                coll[base] += b * m
                coll_static[base] += b
                count[base] += 1
            elif kind == "dot":
                f = _dot_flops(op, symbols)
                dflops += f * m
                dflops_static += f
            elif kind == "while":
                attrs = dict(_WHILE_ATTR_RE.findall(op.line))
                cn = attrs.get("condition")
                if cn in comps:
                    trips.append(_while_trip_count(comps[cn]))
    return HLOStats(
        collective_bytes=dict(coll),
        collective_bytes_static=dict(coll_static),
        collective_count=dict(count),
        dot_flops=dflops,
        dot_flops_static=dflops_static,
        while_trips=trips,
    )


def span_attrs(stats: HLOStats, **extra) -> dict:
    """Flatten an HLOStats into span attributes (obs/trace.py): scalar
    totals plus per-kind collective bytes, so a compiled program's span in
    the exported timeline carries its communication/compute footprint."""
    attrs = dict(
        dot_flops=stats.dot_flops,
        collective_bytes=stats.total_collective_bytes,
        collective_launches=sum(stats.collective_count.values()),
        while_trips=sum(stats.while_trips),
    )
    for kind, b in sorted(stats.collective_bytes.items()):
        attrs[f"collective_bytes.{kind}"] = b
    attrs.update(extra)
    return attrs
