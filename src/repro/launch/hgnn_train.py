"""Mesh-scale HGNN training launcher (DESIGN.md §11).

    PYTHONPATH=src python -m repro.launch.hgnn_train --dataset acm --model HAN \
        --steps 100 --lanes 2 --backend kernel

Composes the pieces the repo already had into the paper's training
posture: the ``lanes`` sharding rules + a dedicated lane mesh
(independency-aware parallel execution, §4.2.1), a MultiLanePlan built by
the workload-aware scheduler, and HAN's NA running through the fused
multigraph Pallas kernel — one forward and one backward launch per lane
shard (``multilane_na_sharded(backend="kernel")``, custom VJP).  The
fault-tolerant ``train_loop`` is reused end to end: atomic checkpoints,
counter-based data state, ``--crash-at`` fault injection, and *elastic
lane restarts* — resume the same checkpoint directory with a different
``--lanes`` and the state restores bit-identically onto the new mesh
(checkpoints store logical arrays; the plan is rebuilt per run, the
forward is bit-identical for any lane count, and gradients agree to f32
tolerance — the lane partition only regroups the cross-unit reduction).

R-GAT trains through its per-relation forward with the same fused
multigraph kernel per relation (its relation-specific projections keep it
off the consolidated one-launch plan).  Compiled kernels degrade to the
interpreter on CPU-only hosts (same kernel body, same numbers).
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from ..core import NABackend, cpu_fallback, similarity_schedule
from ..core.multilane import build_multilane_plan, resolve_multilane_backend
from ..data import SyntheticHGNNData
from ..dist.sharding import lane_axes, make_rules, param_shardings, use_rules
from ..graphs import (
    build_semantic_graphs,
    dataset_metapaths,
    dataset_target,
    synthetic_hetgraph,
    synthetic_labels,
)
from ..models.hgnn import MODELS, han_forward_multilane, prepare_data
from ..obs import disable_tracing, enable_tracing, get_registry
from ..obs.characterize import characterize_hgnn
from ..optim import AdamWConfig
from ..train import (
    hgnn_train_state_axes,
    init_hgnn_train_state,
    make_hgnn_train_step,
    train_loop,
)
from .mesh import make_lane_mesh

DATASETS = ("acm", "imdb", "dblp")

# model.init keyword vocabularies differ (HAN takes att_dim, R-GAT layers)
_INIT_KW = {
    "HAN": lambda hidden, heads: dict(hidden=hidden, heads=heads, att_dim=2 * hidden),
    "R-GAT": lambda hidden, heads: dict(hidden=hidden, heads=heads, layers=2),
}


def build_problem(
    dataset: str,
    *,
    scale: float = 0.1,
    feat_scale: float = 0.1,
    block: int = 128,
    max_edges: int = 400_000,
    seed: int = 0,
):
    """Synthesize the Table-5 HetG and its device-resident training data,
    semantic graphs ordered by the similarity schedule (FP reuse)."""
    g = synthetic_hetgraph(dataset, scale=scale, feat_scale=feat_scale, seed=seed)
    target, ncls = dataset_target(dataset)
    labels = synthetic_labels(g, dataset, seed=seed)
    sgs = build_semantic_graphs(g, dataset_metapaths(dataset), max_edges=max_edges)
    order, _ = similarity_schedule(sgs, g.vertex_counts)
    data = prepare_data(g, [sgs[i] for i in order], target, ncls, labels, block=block)
    return g, data


def run_training(
    *,
    dataset: str = "acm",
    model_name: str = "HAN",
    steps: int = 100,
    lanes: int = 1,
    model_split: int = 1,
    plan_lanes: int | None = None,
    backend: str = "kernel",
    hidden: int = 16,
    heads: int = 4,
    lr: float = 5e-3,
    batch: int = 0,  # labeled minibatch size; 0 = full batch
    block: int = 128,
    scale: float = 0.1,
    feat_scale: float = 0.1,
    max_edges: int = 400_000,
    seed: int = 0,
    ckpt_dir: str | None = None,
    ckpt_every: int = 50,
    resume: bool = True,
    crash_at: int | None = None,
    log_every: int = 10,
    log=print,
    trace: str | None = None,        # Chrome-trace JSON output path
    metrics_out: str | None = None,  # metrics-registry snapshot path
    registry=None,
):
    """Train one HGNN on one dataset under the lanes posture.

    Returns ``(state, history, meta)`` — meta records the resolved mesh /
    plan / backend so callers (benchmarks, tests) can assert on them.

    ``trace=`` enables sync-span tracing for the whole run and writes a
    Chrome-trace/Perfetto JSON on exit.  For HAN it also runs the eager
    per-stage characterization pass (``obs/characterize.py``) before the
    jitted steady state, so the timeline carries honest FP/theta/NA/FA
    stage timing with one lane row per semantic graph — the paper's §3
    characterization reproduced on the live model.  ``metrics_out=``
    snapshots the metrics registry (step-time histogram, loss/grad-norm
    gauges, characterization stage histogram) to JSON.
    """
    reg = registry if registry is not None else get_registry()
    tracer = enable_tracing(sync=True) if trace else None
    g, data = build_problem(
        dataset, scale=scale, feat_scale=feat_scale, block=block,
        max_edges=max_edges, seed=seed,
    )
    model = MODELS[model_name]
    n_target = g.vertex_counts[data.target_type]

    n_dev = len(jax.devices())
    assert lanes * model_split <= n_dev, (
        f"mesh {lanes}x{model_split} needs {lanes * model_split} devices, have {n_dev}"
    )
    mesh = make_lane_mesh(lanes, model_split)
    rules = make_rules(parallelism="lanes")

    if model_name == "HAN":
        # consolidated path: ONE fused NA dispatch for all relations per
        # step, lane-sharded over the mesh (the tentpole configuration)
        n_plan_lanes = plan_lanes or lanes
        assert n_plan_lanes % lanes == 0, (n_plan_lanes, lanes)
        plan = build_multilane_plan(data.graphs, n_plan_lanes)
        na_backend = resolve_multilane_backend(backend)
        forward_fn = lambda p: han_forward_multilane(
            p, data, plan, mesh=mesh, lane_axes=lane_axes(rules), backend=na_backend
        )
        meta_backend = na_backend
    else:
        # per-relation projections -> per-relation fused kernel launches
        plan = None
        nab = cpu_fallback(
            {"kernel": NABackend.MULTIGRAPH,
             "kernel_interpret": NABackend.MULTIGRAPH_INTERPRET,
             "reference": NABackend.BLOCK}[backend]
        )
        forward_fn = lambda p: model.forward(p, data, backend=nab)
        meta_backend = nab.value

    opt = AdamWConfig(lr=lr, weight_decay=0.0)
    pipeline = SyntheticHGNNData(
        num_vertices=n_target,
        batch_size=batch if batch > 0 else n_target,
        seed=seed,
    )

    char = None
    try:
        with mesh, use_rules(rules):
            state = init_hgnn_train_state(
                model, jax.random.key(seed), data, opt, **_INIT_KW[model_name](hidden, heads)
            )
            axes = hgnn_train_state_axes(state, opt)
            state_sh = param_shardings(mesh, rules, axes)
            state = jax.device_put(state, state_sh)
            n_params = sum(
                int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(state.params)
            )
            log(
                f"[hgnn_train] {model_name}/{dataset} params={n_params/1e6:.2f}M "
                f"edges={sum(b.num_edges for b in data.graphs)} mesh=lane{lanes}xmodel"
                f"{model_split} backend={meta_backend}"
            )
            if tracer is not None and model_name == "HAN":
                # eager per-stage pass (paper §3 measured): honest FP/theta/
                # NA/FA spans, one lane row per semantic graph — the jitted
                # steady state below only yields whole-step spans.
                char = characterize_hgnn(
                    state.params, data, backend=NABackend.BLOCK, registry=reg
                )
                log(
                    "[characterize] "
                    + " ".join(f"{k}={v:.0f}us" for k, v in char["stage_us"].items())
                )
            step_fn = make_hgnn_train_step(forward_fn, data, opt)
            state, history = train_loop(
                state=state, train_step=step_fn, data=pipeline, steps=steps,
                ckpt_dir=ckpt_dir, ckpt_every=ckpt_every, resume=resume,
                crash_at=crash_at, log_every=log_every, log=log,
                registry=reg, state_shardings=state_sh,
            )
    finally:
        if tracer is not None:
            tracer.export_chrome_trace(trace)
            disable_tracing()
    if metrics_out:
        reg.export_json(metrics_out)

    meta = dict(
        dataset=dataset, model=model_name, backend=str(meta_backend),
        lanes=lanes, model_split=model_split,
        plan_lanes=None if plan is None else plan.num_lanes,
        n_params=n_params, n_target=n_target,
        characterize=char,
    )
    return state, history, meta


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="acm", choices=DATASETS)
    ap.add_argument("--model", default="HAN", choices=sorted(_INIT_KW))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--lanes", type=int, default=1, help="lane mesh axis size")
    ap.add_argument("--model-split", type=int, default=1, help="model mesh axis size")
    ap.add_argument(
        "--plan-lanes", type=int, default=None,
        help="work-unit partition lanes (default: mesh lanes; must be a multiple)",
    )
    ap.add_argument(
        "--backend", default="kernel",
        choices=("reference", "kernel", "kernel_interpret"),
        help="multilane NA executor (kernel = fused multigraph Pallas launch/shard)",
    )
    ap.add_argument("--hidden", type=int, default=16)
    ap.add_argument("--heads", type=int, default=4)
    ap.add_argument("--lr", type=float, default=5e-3)
    ap.add_argument("--batch", type=int, default=0, help="labeled minibatch (0 = full)")
    ap.add_argument("--block", type=int, default=128, help="dst block size (paper: 128)")
    ap.add_argument("--scale", type=float, default=0.1)
    ap.add_argument("--feat-scale", type=float, default=0.1)
    ap.add_argument("--max-edges", type=int, default=400_000)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--crash-at", type=int, default=None, help="fault injection (tests)")
    ap.add_argument("--out", default=None, help="write the loss trajectory as JSON")
    ap.add_argument(
        "--trace", default=None, metavar="PATH",
        help="write a Chrome-trace/Perfetto JSON of the run (enables sync spans "
             "+ the eager per-stage characterization pass for HAN)",
    )
    ap.add_argument(
        "--metrics", default=None, metavar="PATH",
        help="write a metrics-registry JSON snapshot (step-time histogram, "
             "loss/grad-norm gauges, characterization stage histogram)",
    )
    args = ap.parse_args()

    state, history, meta = run_training(
        dataset=args.dataset, model_name=args.model, steps=args.steps,
        lanes=args.lanes, model_split=args.model_split, plan_lanes=args.plan_lanes,
        backend=args.backend, hidden=args.hidden, heads=args.heads, lr=args.lr,
        batch=args.batch, block=args.block, scale=args.scale,
        feat_scale=args.feat_scale, max_edges=args.max_edges, seed=args.seed,
        ckpt_dir=args.ckpt, ckpt_every=args.ckpt_every, resume=not args.no_resume,
        crash_at=args.crash_at, trace=args.trace, metrics_out=args.metrics,
    )
    print(
        f"final loss {history[-1]['loss']:.4f} (start {history[0]['loss']:.4f}) "
        f"acc {history[-1]['acc']:.3f}"
    )
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"meta": meta, "history": history}, f, indent=1)
        print(f"wrote {args.out}")
    if args.trace:
        print(f"wrote {args.trace} (open at https://ui.perfetto.dev)")
    if args.metrics:
        print(f"wrote {args.metrics}")


if __name__ == "__main__":
    main()
