"""HGNN serving launcher: stepped graph-request inference with the
cross-request FP cache and similarity-aware admission.

    PYTHONPATH=src python -m repro.launch.hgnn_serve --dataset imdb --compare

Builds the named Table-5 HetGraph, submits a round-robin request mix over
its metapaths, and drives serve/hgnn_engine.py.  ``--compare`` runs the
same mix under FIFO and similarity-aware admission and reports the
measured FP-stage compute reduction (the serving-tier counterpart of the
paper's Fig. 15 DRAM-fetch reduction).  ``--na-backend multigraph`` is
the TPU path (one fused Pallas launch per step); ``multigraph_interpret``
validates the same kernel on CPU; ``block`` is the pure-jnp fallback.
``--na-backend fused-fp`` runs the stage-fusion megakernel: on a cache
miss the target type's FP happens inside the NA launch (DESIGN.md §10);
on a full-table cache hit the engine dispatches the projected multigraph
path instead.  Compiled Pallas backends degrade to their interpret
variants on CPU-only hosts.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax

from ..core.fusion import NABackend, cpu_fallback
from ..graphs import dataset_metapaths, dataset_target, synthetic_hetgraph
from ..obs import MetricsRegistry, disable_tracing, enable_tracing
from ..serve.hgnn_engine import HGNNEngine, make_request_mix

_BACKENDS = {
    "segment": NABackend.SEGMENT,
    "block": NABackend.BLOCK,
    "multigraph": NABackend.MULTIGRAPH,
    "multigraph_interpret": NABackend.MULTIGRAPH_INTERPRET,
    "fused_fp": NABackend.FUSED_FP,
    "fused-fp": NABackend.FUSED_FP,  # alias
    "fused_fp_interpret": NABackend.FUSED_FP_INTERPRET,
}


def _resolve_backend(name: str) -> NABackend:
    backend = _BACKENDS[name]
    resolved = cpu_fallback(backend)
    if resolved is not backend:
        print(
            f"note: --na-backend {name} needs a TPU; falling back to "
            f"{resolved.value} on {jax.default_backend()}",
            file=sys.stderr,
        )
    return resolved


def _target_metapaths(name: str, target: str) -> list[tuple[str, ...]]:
    return [tuple(mp) for mp in dataset_metapaths(name) if mp[0] == target and mp[-1] == target]


def serve_mix(graph, target, clusters, args, admission, registry=None) -> dict:
    eng = HGNNEngine(
        graph,
        target_type=target,
        hidden=args.hidden,
        heads=args.heads,
        num_slots=args.slots,
        cache_bytes=args.cache_kb * 1024,
        cache_block_rows=args.cache_block_rows,
        cache_policy=args.policy,
        admission=admission,
        backend=_resolve_backend(args.na_backend),
        block=args.block,
        max_edges=args.max_edges,
        registry=registry,
    )
    for req in make_request_mix(0, clusters, repeats=args.repeats):
        eng.submit(req)
    t0 = time.perf_counter()
    eng.run()
    dt = time.perf_counter() - t0
    m = eng.metrics()
    m["wall_s"] = dt
    m["admission"] = admission
    return m


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="imdb", choices=("imdb", "acm", "dblp"))
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--feat-scale", type=float, default=0.02)
    ap.add_argument("--repeats", type=int, default=4, help="requests per metapath cluster")
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--cache-kb", type=int, default=48, help="FP cache capacity (0 disables)")
    ap.add_argument("--cache-block-rows", type=int, default=64)
    ap.add_argument("--policy", default="lru", choices=("lru", "similarity"))
    ap.add_argument("--admission", default="similarity", choices=("similarity", "fifo"))
    ap.add_argument("--na-backend", default="block", choices=sorted(_BACKENDS))
    ap.add_argument("--hidden", type=int, default=8)
    ap.add_argument("--heads", type=int, default=2)
    ap.add_argument("--block", type=int, default=8, help="dst block size for the NA formats")
    ap.add_argument("--max-edges", type=int, default=20_000)
    ap.add_argument("--compare", action="store_true", help="run FIFO vs similarity admission")
    ap.add_argument(
        "--trace", default=None, metavar="PATH",
        help="write a Chrome-trace/Perfetto JSON of the serving run (sync spans: "
             "serve/step + FP/theta/NA spans, one lane row per slot)",
    )
    ap.add_argument(
        "--metrics", default=None, metavar="PATH",
        help="write the engine metrics registry (counters, cache gauges, "
             "per-step latency histogram) as JSON",
    )
    args = ap.parse_args()

    graph = synthetic_hetgraph(args.dataset, scale=args.scale, feat_scale=args.feat_scale, seed=0)
    target, _ = dataset_target(args.dataset)
    clusters = [[mp] for mp in _target_metapaths(args.dataset, target)]
    assert clusters, f"{args.dataset}: no target->target metapaths"

    tracer = enable_tracing(sync=True) if args.trace else None
    # one registry across runs: --compare accumulates both admissions'
    # counters; gauges reflect the last engine to step
    reg = MetricsRegistry() if args.metrics else None
    try:
        if args.compare:
            fifo = serve_mix(graph, target, clusters, args, "fifo", registry=reg)
            sim = serve_mix(graph, target, clusters, args, "similarity", registry=reg)
            reduction = fifo["fp_rows_computed"] / max(sim["fp_rows_computed"], 1)
            print(json.dumps(dict(fifo=fifo, similarity=sim,
                                  fp_rows_fifo_over_similarity=reduction), indent=1))
        else:
            print(json.dumps(
                serve_mix(graph, target, clusters, args, args.admission, registry=reg),
                indent=1,
            ))
    finally:
        if tracer is not None:
            tracer.export_chrome_trace(args.trace)
            disable_tracing()
            print(f"wrote {args.trace} (open at https://ui.perfetto.dev)", file=sys.stderr)
    if reg is not None:
        reg.export_json(args.metrics)
        print(f"wrote {args.metrics}", file=sys.stderr)


if __name__ == "__main__":
    main()
