import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax-importing module: jax locks
# the device count at first init.  512 placeholder host devices back the
# production meshes (16×16 single-pod, 2×16×16 multi-pod).  Tests and
# benches never import this module, so they see 1 device.
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={os.environ['REPRO_DRYRUN_DEVICES']}"
    )

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from ..configs import ARCH_IDS, SHAPES, Shape, cell_supported, get_config
from ..dist.sharding import Rules, make_rules, param_shardings, use_rules
from ..models.lm.api import LMApi, build
from ..models.lm.config import LMConfig
from ..optim import AdamWConfig
from ..serve.engine import ServeState, init_serve_state, make_serve_step
from ..train.step import init_train_state, make_train_step, train_state_axes
from .hlostats import analyze, normalize_cost_analysis
from .mesh import make_production_mesh

# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS = 197e12      # bf16 FLOP/s per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link


def opt_config(cfg: LMConfig) -> AdamWConfig:
    big = cfg.param_count() > 5e10
    if big:
        # >100B: factored second moment, no master (pure-bf16 posture with
        # TPU stochastic rounding) — required to fit 16 GB/chip (DESIGN §7)
        return AdamWConfig(factored=True, master_fp32=False)
    return AdamWConfig()


def pick_microbatches(cfg: LMConfig, default: int | None = None) -> int:
    """None -> heuristic (16 for >50B models, else 8); explicit values honored."""
    if default is None:
        return 16 if cfg.param_count() > 5e10 else 8
    return default


def input_specs(cfg: LMConfig, shape: Shape) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        batch = {"tokens": jax.ShapeDtypeStruct((b, s + 1), jnp.int32)}
        if cfg.frontend == "audio":
            batch["frames"] = jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        if cfg.frontend == "vision":
            batch["visual_embeds"] = jax.ShapeDtypeStruct((b, 256, cfg.d_model), jnp.bfloat16)
            batch["positions"] = jax.ShapeDtypeStruct((b, s, 3), jnp.int32)
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if cfg.frontend == "audio":
            batch["frames"] = jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        if cfg.frontend == "vision":
            batch["visual_embeds"] = jax.ShapeDtypeStruct((b, 256, cfg.d_model), jnp.bfloat16)
            batch["positions"] = jax.ShapeDtypeStruct((b, s, 3), jnp.int32)
        return batch
    # decode: one new token against a cache of seq_len
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}


def _dim_heuristic_spec(
    leaf, *, batch: int, lens: tuple[int, ...], data_axes
) -> PartitionSpec:
    """Shard cache-like tensors: first dim == batch -> data axes, first
    dim matching a cache length -> model (sequence-sharded KV)."""
    model_axes = ("model",)
    used_data = used_model = False
    parts = []
    data_sz = 1
    if data_axes:
        for a in data_axes:
            data_sz *= {"pod": 2, "data": 16, "model": 16}.get(a, 1)
    for d in leaf.shape:
        if not used_data and data_axes and d == batch and d % data_sz == 0 and d > 1:
            parts.append(tuple(data_axes) if len(data_axes) > 1 else data_axes[0])
            used_data = True
        elif not used_model and d in lens and d % 16 == 0:
            parts.append("model")
            used_model = True
        else:
            parts.append(None)
    return PartitionSpec(*parts)


def serve_state_shardings(
    mesh, rules: Rules, state_abs: ServeState, batch: int, cache_len: int,
    cfg: LMConfig, data_axes=None,
):
    lens = (cache_len,)
    if cfg.window:
        lens = (cache_len, min(cache_len, cfg.window))
    if data_axes is None:
        data_axes = rules.table.get("act_batch")

    def leaf_sh(x):
        return NamedSharding(mesh, _dim_heuristic_spec(x, batch=batch, lens=lens, data_axes=data_axes))

    caches = jax.tree_util.tree_map(leaf_sh, state_abs.caches)
    cross = jax.tree_util.tree_map(leaf_sh, state_abs.cross_kv)
    return ServeState(
        caches=caches,
        cache_pos=NamedSharding(mesh, PartitionSpec()),
        cross_kv=cross,
    )


def _tokens_sharding(mesh, rules: Rules, b: int):
    data_axes = rules.table.get("act_batch")
    spec = PartitionSpec(data_axes if data_axes and len(data_axes) > 1 else (data_axes[0] if data_axes else None))
    return NamedSharding(mesh, spec)


def model_flops(cfg: LMConfig, shape: Shape) -> float:
    """Analytic MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), N = active."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def lower_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    microbatches: int | None = None,
    seq_shard: bool = False,
    save_hlo: str | None = None,
    remat: str | None = None,
    parallelism: str = "tp",
    grad_dtype: str | None = None,
) -> dict:
    cfg = get_config(arch)
    if remat is not None:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, remat=remat)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    result: dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "kind": shape.kind,
        "params_b": cfg.param_count() / 1e9,
        "active_params_b": cfg.active_param_count() / 1e9,
    }
    ok, why = cell_supported(cfg, shape)
    if not ok:
        result.update(status="skipped", reason=why)
        return result

    chips = 512 if multi_pod else 256
    data_size = (2 * 16) if multi_pod else 16
    batch_shard = shape.global_batch % data_size == 0 and shape.global_batch >= data_size
    rules = make_rules(
        multi_pod=multi_pod, fsdp=cfg.fsdp, seq_shard=seq_shard,
        batch_shard=batch_shard, parallelism=parallelism,
    )
    result["parallelism"] = parallelism
    mesh = make_production_mesh(multi_pod=multi_pod)
    api = build(cfg)
    t0 = time.time()
    try:
        with mesh, use_rules(rules):
            if shape.kind == "train":
                mb = pick_microbatches(cfg, microbatches)
                while shape.global_batch % mb or (shape.global_batch // mb) % data_size:
                    mb //= 2  # keep each microbatch shardable over data
                mb = max(mb, 1)
                result["microbatches"] = mb
                opt = opt_config(cfg)
                state_abs = jax.eval_shape(
                    lambda k: init_train_state(api, k, opt), jax.random.key(0)
                )
                axes = train_state_axes(api, opt, state_abs.params)
                state_sh = param_shardings(mesh, rules, axes)
                batch_abs = input_specs(cfg, shape)
                batch_sh = {
                    k: NamedSharding(
                        mesh,
                        rules.spec(("act_batch",) + (None,) * (v.ndim - 1)),
                    )
                    for k, v in batch_abs.items()
                }
                step = make_train_step(api, opt, microbatches=mb, grad_dtype=grad_dtype)
                lowered = jax.jit(
                    step,
                    in_shardings=(state_sh, batch_sh),
                    out_shardings=(state_sh, None),
                    donate_argnums=(0,),
                ).lower(state_abs, batch_abs)
            elif shape.kind == "prefill":
                params_abs = jax.eval_shape(api.init, jax.random.key(0))
                p_sh = param_shardings(mesh, rules, api.axes())
                batch_abs = input_specs(cfg, shape)
                batch_sh = {
                    k: NamedSharding(
                        mesh, rules.spec(("act_batch",) + (None,) * (v.ndim - 1))
                    )
                    for k, v in batch_abs.items()
                }

                def prefill_forward(params, batch):
                    toks = batch.pop("tokens")
                    logits, _ = api.forward(params, toks, **batch)
                    return logits

                lowered = jax.jit(
                    prefill_forward, in_shardings=(p_sh, batch_sh)
                ).lower(params_abs, batch_abs)
            else:  # decode
                params_abs = jax.eval_shape(api.init, jax.random.key(0))
                p_sh = param_shardings(mesh, rules, api.axes())
                b, s = shape.global_batch, shape.seq_len
                state_abs = jax.eval_shape(
                    lambda: init_serve_state(api, b, s, dtype=jnp.bfloat16, filled=s - 1)
                )
                cache_data_axes = ("pod", "data") if multi_pod else ("data",)
                if not (b % data_size == 0 and b >= data_size):
                    cache_data_axes = None
                st_sh = serve_state_shardings(
                    mesh, rules, state_abs, b, s, cfg, data_axes=cache_data_axes
                )
                tok_abs = jax.ShapeDtypeStruct((b, 1), jnp.int32)
                tok_sh = _tokens_sharding(mesh, rules, b)
                serve_step = make_serve_step(api)
                lowered = jax.jit(
                    serve_step,
                    in_shardings=(p_sh, st_sh, tok_sh),
                    out_shardings=(None, st_sh),
                    donate_argnums=(1,),
                ).lower(params_abs, state_abs, tok_abs)

            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
    except Exception as e:  # a failure here is a bug in the system
        result.update(status="failed", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
        return result

    mem = compiled.memory_analysis()
    cost = normalize_cost_analysis(compiled.cost_analysis())
    hlo = compiled.as_text()
    stats = analyze(hlo)
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)

    mf = model_flops(cfg, shape)
    hlo_flops_dev = stats.dot_flops  # per device, loop-corrected
    coll_dev = stats.total_collective_bytes
    bytes_dev = float(cost.get("bytes accessed", 0.0))

    result.update(
        status="ok",
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        memory=dict(
            argument_bytes=mem.argument_size_in_bytes,
            output_bytes=mem.output_size_in_bytes,
            temp_bytes=mem.temp_size_in_bytes,
            alias_bytes=mem.alias_size_in_bytes,
            per_device_total=mem.argument_size_in_bytes
            + mem.output_size_in_bytes
            + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
        ),
        cost_analysis=dict(
            flops=float(cost.get("flops", 0.0)),
            bytes_accessed=bytes_dev,
        ),
        hlo_stats=dict(
            dot_flops_per_device=hlo_flops_dev,
            dot_flops_static=stats.dot_flops_static,
            collective_bytes=stats.collective_bytes,
            collective_bytes_static=stats.collective_bytes_static,
            collective_count=stats.collective_count,
            while_trips=stats.while_trips[:32],
        ),
        model_flops=mf,
        chips=chips,
        roofline=dict(
            compute_s=hlo_flops_dev / PEAK_FLOPS,
            # memory term: loop-corrected HLO byte traffic is not separable
            # from cost_analysis; use bytes_accessed (static) as the floor
            # and the analytic traffic model in benchmarks/roofline.py
            memory_s_floor=bytes_dev / HBM_BW,
            collective_s=coll_dev / ICI_BW,
            model_flops_utilization=mf / max(hlo_flops_dev * chips, 1.0),
        ),
    )
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'pod2x16x16' if mp else 'pod16x16'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    with open(path) as f:
                        prev = json.load(f)
                    if prev.get("status") in ("ok", "skipped"):
                        print(f"[skip-cached] {tag}")
                        continue
                t0 = time.time()
                res = lower_cell(
                    arch, shape, multi_pod=mp,
                    microbatches=args.microbatches, seq_shard=args.seq_shard,
                )
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
                status = res["status"]
                if status == "failed":
                    n_fail += 1
                    print(f"[FAIL] {tag}: {res['error']}")
                else:
                    extra = ""
                    if status == "ok":
                        gb = res["memory"]["per_device_total"] / 2**30
                        extra = f" mem/dev={gb:.2f}GiB compile={res['compile_s']}s"
                    print(f"[{status}] {tag}{extra} ({time.time()-t0:.1f}s)")
    if n_fail:
        raise SystemExit(f"{n_fail} cells failed")


if __name__ == "__main__":
    main()
