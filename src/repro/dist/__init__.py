"""Distribution subsystem: logical-axis sharding rules (DESIGN.md §5).

``sharding`` maps *logical* tensor axes (``"embed"``, ``"act_batch"``,
``"lane"``, ...) onto *physical* mesh axes (``"pod"``, ``"data"``,
``"model"``, ``"lane"``).  Models annotate tensors with logical names
only; which mesh axis (if any) a name lands on is decided once, at
launch time, by ``make_rules`` — so the same model code runs 1-device
CPU smoke tests and 512-chip multi-pod dry-runs unchanged.
"""
from .sharding import (
    Rules,
    active_rules,
    lane_axes,
    make_rules,
    param_shardings,
    shard,
    use_rules,
)

__all__ = [
    "Rules",
    "active_rules",
    "lane_axes",
    "make_rules",
    "param_shardings",
    "shard",
    "use_rules",
]
