"""Logical-axis sharding rules — the one place physical layout is decided.

Model code never names a mesh axis.  It annotates tensors with *logical*
axes (``shard(h, "act_batch", None, "act_mlp")``; parameter specs carry
``("embed", "heads")``) and the active :class:`Rules` — installed by the
launcher with ``use_rules`` — translates those names to a
``PartitionSpec`` over whatever mesh is in scope.  Outside a rules
context every annotation is a no-op, which is what lets the full model
stack run on a bare 1-CPU pytest without ever mentioning meshes.

Rule construction (``make_rules``) encodes the parallelism postures of
DESIGN.md §5:

* ``"tp"``    — data-parallel batch × tensor-parallel weights (default);
  ``fsdp=True`` additionally shards the ``embed`` dim of every weight
  over the data axes (ZeRO-3); ``seq_shard=True`` sequence-shards
  activations over ``model``.
* ``"sp"``    — sequence parallelism: weights model-replicated,
  activations sharded (batch over ``data``, sequence over ``model``).
* ``"serve2d"`` — decode posture: weights stay resident (``embed`` over
  ``data``, ``mlp``/``heads`` over ``model``), the batch is NOT sharded,
  activation feature dims are.
* ``"lanes"`` — the paper's independency-aware multi-lane execution
  (HiHGNN §4.2): semantic-graph work units ride a dedicated ``lane``
  mesh axis (see ``launch/mesh.py:make_lane_mesh`` and
  ``core/multilane.py:multilane_na_sharded``), head/feature dims ride
  ``model``.

Compounding and conflict rules (pinned by tests/test_dist.py):

* multi-pod compounds the data axes: ``("pod", "data")`` acts as one
  logical data dimension and appears as a tuple entry in the spec;
* within a single spec each mesh axis is used at most once — a logical
  axis whose mesh axes were already consumed maps to ``None`` (the
  duplicate is dropped, first occurrence wins);
* ``batch_shard=False`` gates ``act_batch`` off entirely (1-device
  smoke, or global batch not divisible by the data axes).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading

import jax
from jax.interpreters import pxla
from jax.sharding import NamedSharding, PartitionSpec

# Logical parameter axes that ride the tensor-parallel `model` mesh axis
# under the "tp" posture.  Everything not named in a table replicates.
_MODEL_PARAM_AXES = (
    "heads",
    "kv_heads",
    "mlp",
    "vocab",
    "experts",
    "ssm_inner",
    "rnn",
)

# Activation counterparts (the `act_` namespace keeps activation layout
# decisions independent of weight layout — serve2d shards one without
# the other).
_MODEL_ACT_AXES = ("act_heads", "act_mlp", "act_vocab", "act_experts")


@dataclasses.dataclass(frozen=True)
class Rules:
    """Immutable logical-axis → mesh-axes table with spec translation.

    ``table`` maps a logical axis name to a tuple of mesh axis names
    (compound axes allowed, e.g. ``("pod", "data")``) or ``None`` for
    replicated.  Unknown names are replicated — annotating model code
    with a new logical axis is always safe before any rule names it.
    """

    table: dict[str, tuple[str, ...] | None]
    name: str = "tp"

    def spec(self, axes: tuple[str | None, ...]) -> PartitionSpec:
        """Translate a logical-axes tuple into a PartitionSpec.

        Each mesh axis is used at most once per spec: later logical axes
        whose mesh axes were already consumed collapse to ``None``.
        """
        used: set[str] = set()
        parts: list[str | tuple[str, ...] | None] = []
        for name in axes:
            mesh_axes = self.table.get(name) if name is not None else None
            if not mesh_axes:
                parts.append(None)
                continue
            fresh = tuple(a for a in mesh_axes if a not in used)
            used.update(fresh)
            if not fresh:
                parts.append(None)
            elif len(fresh) == 1:
                parts.append(fresh[0])
            else:
                parts.append(fresh)
        return PartitionSpec(*parts)

    def mesh_axes(self, name: str) -> tuple[str, ...] | None:
        """Mesh axes backing one logical axis (None = replicated)."""
        return self.table.get(name)


def make_rules(
    *,
    multi_pod: bool = False,
    fsdp: bool = False,
    seq_shard: bool = False,
    batch_shard: bool = True,
    parallelism: str = "tp",
) -> Rules:
    """Build the Rules for one launch posture (see module docstring)."""
    data: tuple[str, ...] = ("pod", "data") if multi_pod else ("data",)
    model: tuple[str, ...] = ("model",)
    lane: tuple[str, ...] = ("pod", "lane") if multi_pod else ("lane",)

    table: dict[str, tuple[str, ...] | None]
    if parallelism == "tp":
        table = {
            "act_batch": data if batch_shard else None,
            "act_seq": model if seq_shard else None,
            "act_qseq": model if seq_shard else None,
            "act_embed": None,
            "embed": data if fsdp else None,
            "layers": None,
        }
        table.update({a: model for a in _MODEL_PARAM_AXES})
        table.update({a: model for a in _MODEL_ACT_AXES})
    elif parallelism == "sp":
        # Sequence parallelism: weights model-replicated, activations
        # carry all the sharding (batch over data, sequence over model).
        table = {
            "act_batch": data if batch_shard else None,
            "act_seq": model,
            "act_qseq": model,
            "act_embed": None,
            "embed": data if fsdp else None,
            "layers": None,
        }
        table.update({a: None for a in _MODEL_PARAM_AXES})
        table.update({a: None for a in _MODEL_ACT_AXES})
    elif parallelism == "serve2d":
        # Decode posture: weights resident in a 2D (data × model) layout,
        # batch replicated (small decode batches), activation feature
        # dims sharded instead.
        table = {
            "act_batch": None,
            "act_seq": None,
            "act_qseq": None,
            "act_embed": data,
            "embed": data,
            "layers": None,
        }
        table.update({a: model for a in _MODEL_PARAM_AXES})
        table.update({a: model for a in _MODEL_ACT_AXES})
    elif parallelism == "lanes":
        # HiHGNN §4.2 multi-lane execution: (semantic graph, dst block
        # row) units ride the `lane` axis; head/feature dims ride
        # `model`.  Vertex/batch-space tensors replicate — every lane
        # gathers the projected features it needs (functional RAB,
        # DESIGN.md §2).  Lane meshes (make_lane_mesh) have no `data`
        # axis, so nothing may map to it here.
        table = {
            "lane": lane,
            "act_lane": lane,
            "act_vertex": None,
            "act_graph": None,
            "act_feat": model,
            "act_batch": None,
            "embed": None,
            "layers": None,
        }
        table.update({a: model for a in _MODEL_PARAM_AXES})
        table.update({a: model for a in _MODEL_ACT_AXES})
    else:
        raise ValueError(f"unknown parallelism {parallelism!r}")
    return Rules(table=table, name=parallelism)


# ---------------------------------------------------------------------------
# Active-rules context.  Thread-local so concurrent lowering (e.g. the
# dry-run sweeping cells from a pool) can't leak rules across threads.
# ---------------------------------------------------------------------------

_state = threading.local()


def active_rules() -> Rules | None:
    """The innermost ``use_rules`` Rules, or None outside any context."""
    stack = getattr(_state, "stack", None)
    return stack[-1] if stack else None


@contextlib.contextmanager
def use_rules(rules: Rules):
    """Install ``rules`` as the ambient sharding rules for the block.

    Nests: the innermost rules win, and the previous rules are restored
    on exit (including on exceptions).
    """
    stack = getattr(_state, "stack", None)
    if stack is None:
        stack = _state.stack = []
    stack.append(rules)
    try:
        yield rules
    finally:
        stack.pop()


def _context_mesh():
    """The mesh installed by ``with mesh:`` — None when there isn't one."""
    mesh = pxla.thread_resources.env.physical_mesh
    return None if mesh.empty else mesh


def shard(x, *axes: str | None):
    """Constrain ``x`` to the active rules' layout for ``axes``.

    No-op unless both a rules context (``use_rules``) and a mesh context
    (``with mesh:``) are active — model code calls this unconditionally
    and single-process tests pay nothing.
    """
    rules = active_rules()
    if rules is None:
        return x
    mesh = _context_mesh()
    if mesh is None:
        return x
    spec = rules.spec(tuple(axes))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _is_axes_leaf(a) -> bool:
    return isinstance(a, tuple) and all(isinstance(x, (str, type(None))) for x in a)


def lane_axes(rules: Rules) -> tuple[str, ...]:
    """The mesh axes backing the logical ``lane`` axis under ``rules``.

    The multilane executors (``core.multilane.multilane_na_sharded``) and
    the training launcher take the lane mesh axes as an argument; callers
    must derive them from the active rules rather than hardcoding
    ``("lane",)`` — under a multi-pod posture the lane dimension compounds
    to ``("pod", "lane")`` and a hardcoded single axis would silently
    leave the pod axis unsharded.
    """
    axes = rules.mesh_axes("lane")
    assert axes, f"rules {rules.name!r} do not map a lane axis"
    return axes


def param_shardings(mesh, rules: Rules, axes):
    """Map a logical-axes pytree to NamedShardings on ``mesh``.

    ``axes`` is the tree produced by ``api.axes()`` /
    ``train_state_axes``: leaves are tuples of logical axis names (or
    None) — one entry per tensor dim, ``()`` for scalars.  ``None``
    subtrees (absent optimizer slots) pass through untouched.
    """
    return jax.tree_util.tree_map(
        lambda a: NamedSharding(mesh, rules.spec(a)), axes, is_leaf=_is_axes_leaf
    )
