from .common import HGNNData, HGNNModel, cross_entropy, prepare_data
from .han import HAN, han_forward, han_forward_multilane, han_forward_staged, init_han
from .rgat import RGAT, init_rgat, rgat_forward
from .rgcn import RGCN, init_rgcn, rgcn_forward
from .shgn import SHGN, init_shgn, shgn_forward

MODELS: dict[str, HGNNModel] = {m.name: m for m in (HAN, RGCN, RGAT, SHGN)}

__all__ = [
    "HGNNData",
    "HGNNModel",
    "cross_entropy",
    "prepare_data",
    "HAN",
    "RGCN",
    "RGAT",
    "SHGN",
    "MODELS",
    "init_han",
    "han_forward",
    "han_forward_multilane",
    "han_forward_staged",
    "init_rgat",
    "rgat_forward",
    "init_rgcn",
    "rgcn_forward",
    "init_shgn",
    "shgn_forward",
]
