"""S-HGN / Simple-HGN (Lv et al., KDD'21).

Table 2 semantics: type-specific FP, GAT-style NA whose logits carry a
learnable *edge-type* term a_e^T (W_r r) — which is constant per relation
and therefore enters our decomposed kernel as the scalar ``edge_bias``
(exactly the coefficient reuse HiHGNN's RAB performs), residual
connections, and no separate SF stage (relations fuse inside NA layers).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core import stages
from ...core.fusion import NABackend, neighbor_aggregate
from ...dist.sharding import shard
from .common import HGNNData, HGNNModel, glorot, split_keys


def init_shgn(
    rng: jax.Array,
    data: HGNNData,
    *,
    hidden: int = 64,
    heads: int = 4,
    layers: int = 2,
    edge_dim: int = 64,
) -> dict:
    dims = data.feature_dims
    n_rel = len(data.graphs)
    keys = iter(split_keys(rng, 4 + len(dims) + layers * (5 + n_rel)))
    # type-specific input projection (the FP stage; done once — RAB reuse)
    fp = {t: glorot(next(keys), (d, heads * hidden)) for t, d in dims.items()}
    layer_params = []
    for _ in range(layers):
        layer_params.append(
            {
                "w": glorot(next(keys), (heads * hidden, heads * hidden)),
                "a_src": glorot(next(keys), (heads, hidden)),
                "a_dst": glorot(next(keys), (heads, hidden)),
                "a_edge": glorot(next(keys), (heads, edge_dim)),
                "r_emb": glorot(next(keys), (n_rel, edge_dim)),
                "w_r": glorot(next(keys), (edge_dim, edge_dim)),
            }
        )
    return {
        "fp": fp,
        "layers": layer_params,
        "w_out": glorot(next(keys), (heads * hidden, data.num_classes)),
        "b_out": jnp.zeros((data.num_classes,)),
    }


def shgn_forward(params, data: HGNNData, *, backend: NABackend = NABackend.SEGMENT):
    heads = params["layers"][0]["a_src"].shape[0]
    # FP: each vertex type projected exactly once
    h = {
        t: shard(data.features[t] @ params["fp"][t], "act_vertex", "act_feat")
        for t in data.features
    }
    for lp in params["layers"]:
        agg: dict[str, list[jnp.ndarray]] = {}
        for i, batch in enumerate(data.graphs):
            hs = (h[batch.src_type] @ lp["w"]).reshape(batch.num_src, heads, -1)
            hd = (h[batch.dst_type] @ lp["w"]).reshape(batch.num_dst, heads, -1)
            th_s, _ = stages.attention_coefficients(hs, lp["a_src"], lp["a_dst"])
            _, th_d = stages.attention_coefficients(hd, lp["a_src"], lp["a_dst"])
            # edge-type attention term: scalar per (relation, head)
            r = lp["r_emb"][i] @ lp["w_r"]  # [edge_dim]
            edge_bias = lp["a_edge"] @ r  # [heads]
            z = neighbor_aggregate(
                batch, th_s, th_d, hs, backend=backend, edge_bias=edge_bias
            )
            agg.setdefault(batch.dst_type, []).append(z.reshape(batch.num_dst, -1))
        h_new = {}
        for t in h:
            if t in agg:
                s = jnp.sum(jnp.stack(agg[t]), axis=0)
                h_new[t] = shard(jax.nn.elu(s) + h[t], "act_vertex", "act_feat")  # residual
            else:
                h_new[t] = h[t]
        h = h_new
    out = h[data.target_type]
    out = out / jnp.maximum(jnp.linalg.norm(out, axis=-1, keepdims=True), 1e-9)
    return out @ params["w_out"] + params["b_out"]


SHGN = HGNNModel(name="S-HGN", init=init_shgn, forward=shgn_forward)
