"""R-GCN — relational GCN (Schlichtkrull et al., ESWC'18).

Table 2 semantics: relation-specific FP h^r = W^r x, mean NA per relation
graph, SF h_v = sum_r z^r_v + W^{c_v} x_v (self loop), ReLU between layers.
Relation-specific projection means FP work scales with #relations — the
paper's observation that R-GCN benefits least from FP reuse.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.fusion import NABackend, mean_aggregate
from ...dist.sharding import shard
from .common import HGNNData, HGNNModel, glorot, split_keys


def init_rgcn(
    rng: jax.Array,
    data: HGNNData,
    *,
    hidden: int = 64,
    layers: int = 3,
) -> dict:
    dims = data.feature_dims
    keys = iter(split_keys(rng, 2 + layers * (len(data.graphs) + len(dims)) + 2))
    layer_params = []
    for layer in range(layers):
        rel_w, self_w = {}, {}
        for i, g in enumerate(data.graphs):
            d_src = dims[g.src_type] if layer == 0 else hidden
            rel_w[f"g{i}"] = glorot(next(keys), (d_src, hidden))
        for t, d in dims.items():
            d_t = d if layer == 0 else hidden
            self_w[t] = glorot(next(keys), (d_t, hidden))
        layer_params.append({"rel": rel_w, "self": self_w})
    return {
        "layers": layer_params,
        "w_out": glorot(next(keys), (hidden, data.num_classes)),
        "b_out": jnp.zeros((data.num_classes,)),
    }


def rgcn_forward(params, data: HGNNData, *, backend: NABackend = NABackend.SEGMENT):
    del backend  # mean aggregation has a single implementation
    h = dict(data.features)
    for lp in params["layers"]:
        # FP (relation-specific) + NA (mean) per relation graph
        agg: dict[str, list[jnp.ndarray]] = {}
        for i, batch in enumerate(data.graphs):
            hr = shard(h[batch.src_type] @ lp["rel"][f"g{i}"], "act_vertex", "act_feat")
            z = mean_aggregate(batch, hr)
            agg.setdefault(batch.dst_type, []).append(z)
        # SF: sum over relations + self transform
        h_new = {}
        for t in h:
            s = h[t] @ lp["self"][t]
            for z in agg.get(t, []):
                s = s + z
            h_new[t] = shard(jax.nn.relu(s), "act_vertex", "act_feat")
        h = h_new
    return h[data.target_type] @ params["w_out"] + params["b_out"]


RGCN = HGNNModel(name="R-GCN", init=init_rgcn, forward=rgcn_forward)
