"""Shared plumbing for the four HGNN models (HAN, R-GCN, R-GAT, S-HGN).

Models are plain (params-pytree, pure-function) pairs: `init(rng, data_meta)
-> params` and `forward(params, data, *, backend, fused) -> logits`.
``fused=False`` runs each coarse stage as its *own* jitted program with
blocking host barriers between them — the traditional staged execution of
Fig. 4(a) that GPU frameworks exhibit.  ``fused=True`` compiles the whole
layer into one XLA program — the bound-aware stage-fusion of Fig. 4(b).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ...core.fusion import NABackend, SemanticGraphBatch, batch_semantic_graph
from ...graphs.hetgraph import HetGraph, SemanticGraph


@dataclasses.dataclass
class HGNNData:
    """Device-resident inputs for one HGNN forward pass."""

    features: dict[str, jnp.ndarray]          # type -> [N_t, D_t]
    graphs: list[SemanticGraphBatch]
    target_type: str
    num_classes: int
    labels: jnp.ndarray | None = None         # [N_target]

    @property
    def feature_dims(self) -> dict[str, int]:
        return {t: int(x.shape[1]) for t, x in self.features.items()}


def _data_flatten(d: HGNNData):
    return (d.features, d.graphs, d.labels), (d.target_type, d.num_classes)


def _data_unflatten(aux, children):
    features, graphs, labels = children
    return HGNNData(features=features, graphs=list(graphs), target_type=aux[0],
                    num_classes=aux[1], labels=labels)


jax.tree_util.register_pytree_node(HGNNData, _data_flatten, _data_unflatten)


def prepare_data(
    g: HetGraph,
    sgs: Sequence[SemanticGraph],
    target_type: str,
    num_classes: int,
    labels: np.ndarray | None = None,
    *,
    block: int = 128,
    with_blocks: bool = True,
) -> HGNNData:
    return HGNNData(
        features={t: jnp.asarray(x) for t, x in g.features.items()},
        graphs=[batch_semantic_graph(s, block=block, with_blocks=with_blocks) for s in sgs],
        target_type=target_type,
        num_classes=num_classes,
        labels=None if labels is None else jnp.asarray(labels),
    )


def glorot(rng: jax.Array, shape: tuple[int, ...]) -> jnp.ndarray:
    fan_in, fan_out = shape[0], shape[-1]
    lim = float(np.sqrt(6.0 / (fan_in + fan_out)))
    return jax.random.uniform(rng, shape, jnp.float32, -lim, lim)


def split_keys(rng: jax.Array, n: int) -> list[jax.Array]:
    return list(jax.random.split(rng, n))


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()


ForwardFn = Callable[..., jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class HGNNModel:
    name: str
    init: Callable[[jax.Array, HGNNData], dict]
    forward: ForwardFn  # (params, data, *, backend) -> logits

    def loss_fn(self, params, data: HGNNData, *, backend: NABackend = NABackend.SEGMENT):
        logits = self.forward(params, data, backend=backend)
        return cross_entropy(logits, data.labels)
