"""R-GAT — relational GAT (Wang et al., ACL'20).

Table 2 semantics: relation-specific FP h^r = W^r x, GAT attention NA per
relation graph, SF h_v = (1/|P|) mean over relations of z^P_v.  Source and
destination endpoints are projected with relation-specific weights (they
may have different raw dims at layer 0), and the GAT logits use the
decomposed theta_src/theta_dst form that the RAB reuses per vertex.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core import stages
from ...core.fusion import NABackend, neighbor_aggregate
from ...dist.sharding import shard
from .common import HGNNData, HGNNModel, glorot, split_keys


def init_rgat(
    rng: jax.Array,
    data: HGNNData,
    *,
    hidden: int = 64,
    heads: int = 4,
    layers: int = 3,
) -> dict:
    dims = data.feature_dims
    keys = iter(split_keys(rng, 2 + layers * (4 * len(data.graphs) + len(dims))))
    layer_params = []
    for layer in range(layers):
        rel = {}
        for i, g in enumerate(data.graphs):
            d_src = dims[g.src_type] if layer == 0 else heads * hidden
            d_dst = dims[g.dst_type] if layer == 0 else heads * hidden
            rel[f"g{i}"] = {
                "w_src": glorot(next(keys), (d_src, heads * hidden)),
                "w_dst": glorot(next(keys), (d_dst, heads * hidden)),
                "a_src": glorot(next(keys), (heads, hidden)),
                "a_dst": glorot(next(keys), (heads, hidden)),
            }
        self_w = {}
        for t, d in dims.items():
            d_t = d if layer == 0 else heads * hidden
            self_w[t] = glorot(next(keys), (d_t, heads * hidden))
        layer_params.append({"rel": rel, "self": self_w})
    return {
        "layers": layer_params,
        "w_out": glorot(next(keys), (heads * hidden, data.num_classes)),
        "b_out": jnp.zeros((data.num_classes,)),
    }


def rgat_forward(params, data: HGNNData, *, backend: NABackend = NABackend.SEGMENT):
    h = dict(data.features)
    heads = params["layers"][0]["rel"]["g0"]["a_src"].shape[0]
    for lp in params["layers"]:
        agg: dict[str, list[jnp.ndarray]] = {}
        for i, batch in enumerate(data.graphs):
            rp = lp["rel"][f"g{i}"]
            # FP (relation-specific) fused with coefficient computation
            hs = shard(h[batch.src_type] @ rp["w_src"], "act_vertex", "act_feat")
            hs = hs.reshape(batch.num_src, heads, -1)
            hd = shard(h[batch.dst_type] @ rp["w_dst"], "act_vertex", "act_feat")
            hd = hd.reshape(batch.num_dst, heads, -1)
            th_s, _ = stages.attention_coefficients(hs, rp["a_src"], rp["a_dst"])
            _, th_d = stages.attention_coefficients(hd, rp["a_src"], rp["a_dst"])
            z = neighbor_aggregate(batch, th_s, th_d, hs, backend=backend)
            agg.setdefault(batch.dst_type, []).append(z.reshape(batch.num_dst, -1))
        h_new = {}
        for t in h:
            if t in agg:
                s = jnp.mean(jnp.stack(agg[t]), axis=0)  # SF: mean over relations
            else:
                s = h[t] @ lp["self"][t]
            h_new[t] = shard(jax.nn.elu(s), "act_vertex", "act_feat")
        h = h_new
    return h[data.target_type] @ params["w_out"] + params["b_out"]


RGAT = HGNNModel(name="R-GAT", init=init_rgat, forward=rgat_forward)
