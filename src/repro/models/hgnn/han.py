"""HAN — Heterogeneous graph Attention Network (Wang et al., WWW'19).

Table 2 semantics: type-specific FP, GAT neighbor attention per metapath
semantic graph, semantic attention fusion (LSF+GSF split per Alg. 2).
Metapath endpoints are all the target type, so FP projects the target
features exactly once and every semantic graph gathers from it — the
functional RAB (DESIGN.md §2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core import stages
from ...core.fusion import (
    _FUSED_FP_BACKENDS,
    _MULTIGRAPH_BACKENDS,
    FusedFPInputs,
    NABackend,
    _pad_rows,
    neighbor_aggregate,
    neighbor_aggregate_multi,
)
from ...core.multilane import MultiLanePlan, multilane_na, multilane_na_sharded
from ...dist.sharding import shard
from .common import HGNNData, HGNNModel, glorot, split_keys


def init_han(
    rng: jax.Array,
    data: HGNNData,
    *,
    hidden: int = 64,
    heads: int = 8,
    att_dim: int = 128,
) -> dict:
    d_in = data.feature_dims[data.target_type]
    n_graphs = len(data.graphs)
    keys = split_keys(rng, 5 + 2 * n_graphs)
    params = {
        "w_fp": glorot(keys[0], (d_in, heads * hidden)),
        "b_fp": jnp.zeros((heads * hidden,)),
        "a_src": jnp.stack([glorot(keys[5 + 2 * i], (heads, hidden)) for i in range(n_graphs)]),
        "a_dst": jnp.stack([glorot(keys[6 + 2 * i], (heads, hidden)) for i in range(n_graphs)]),
        "w_g": glorot(keys[1], (heads * hidden, att_dim)),
        "b_g": jnp.zeros((att_dim,)),
        "q": glorot(keys[2], (att_dim, 1))[:, 0],
        "w_out": glorot(keys[3], (heads * hidden, data.num_classes)),
        "b_out": jnp.zeros((data.num_classes,)),
    }
    return params


def _han_embed(params, data: HGNNData, backend: NABackend):
    """FP -> per-graph (theta, NA, LSF) -> GSF.  Pure (fusable)."""
    x = data.features[data.target_type]
    heads = params["a_src"].shape[1]
    n = x.shape[0]

    z_list, w_list = [], []
    valid_dst = jnp.ones((n,), bool)
    if backend in _FUSED_FP_BACKENDS:
        # Megakernel path (DESIGN.md §10): FP happens INSIDE the NA launch
        # — raw x streams through the fused kernel, h' never materializes
        # in HBM.  One forward (and, training, one backward) launch for
        # the whole layer.
        fp = FusedFPInputs.shared(
            x, params["w_fp"], params["b_fp"], params["a_src"], params["a_dst"]
        )
        z_all = neighbor_aggregate_multi(
            data.graphs, None, None, None, backend=backend, fp=fp
        )  # [G, N, H, Dh]
        for i in range(len(data.graphs)):
            z = jax.nn.elu(z_all[i].reshape(n, -1))
            z = shard(z, "act_vertex", "act_feat")
            w_p = stages.local_semantic_fusion(
                z, params["w_g"], params["b_g"], params["q"], valid_dst
            )
            z_list.append(z)
            w_list.append(w_p)
        fused, beta = stages.global_semantic_fusion(jnp.stack(w_list), jnp.stack(z_list))
        return shard(fused, "act_vertex", "act_feat"), beta

    h = stages.feature_projection(x, params["w_fp"], params["b_fp"])
    h = shard(h, "act_vertex", "act_feat")  # projected-once FP output (RAB)
    hh = h.reshape(n, heads, -1)

    if backend in _MULTIGRAPH_BACKENDS:
        # Consolidated path: all relations' theta in one einsum, all
        # relations' NA in ONE fused multigraph launch (fwd and bwd).
        th_s = jnp.einsum("nhd,ghd->gnh", hh, params["a_src"])
        th_d = jnp.einsum("nhd,ghd->gnh", hh, params["a_dst"])
        z_all = neighbor_aggregate_multi(
            data.graphs, th_s, th_d, hh, backend=backend
        )  # [G, N, H, Dh]
        for i in range(len(data.graphs)):
            z = jax.nn.elu(z_all[i].reshape(n, -1))
            z = shard(z, "act_vertex", "act_feat")
            w_p = stages.local_semantic_fusion(
                z, params["w_g"], params["b_g"], params["q"], valid_dst
            )
            z_list.append(z)
            w_list.append(w_p)
    else:
        for i, batch in enumerate(data.graphs):
            th_s, th_d = stages.attention_coefficients(hh, params["a_src"][i], params["a_dst"][i])
            z = neighbor_aggregate(batch, th_s, th_d, hh, backend=backend)  # [N, H, Dh]
            z = jax.nn.elu(z.reshape(n, -1))
            z = shard(z, "act_vertex", "act_feat")
            w_p = stages.local_semantic_fusion(z, params["w_g"], params["b_g"], params["q"], valid_dst)
            z_list.append(z)
            w_list.append(w_p)
    fused, beta = stages.global_semantic_fusion(jnp.stack(w_list), jnp.stack(z_list))
    return shard(fused, "act_vertex", "act_feat"), beta


def han_forward(params, data: HGNNData, *, backend: NABackend = NABackend.SEGMENT):
    fused, _ = _han_embed(params, data, backend)
    return fused @ params["w_out"] + params["b_out"]


def _han_embed_multilane(
    params,
    data: HGNNData,
    plan: MultiLanePlan,
    *,
    mesh=None,
    lane_axes: tuple[str, ...] = ("lane",),
    backend: str = "reference",
):
    """The consolidated HAN layer over a lane-partitioned work-unit plan.

    Same semantics as the MULTIGRAPH path of ``_han_embed`` — one theta
    einsum for all relations, all NA units in one fused dispatch — but the
    units execute through ``core.multilane``: vmapped lanes on one chip
    (``mesh=None``) or ``shard_map``ped over the mesh's lane axis (paper
    §4.2.1).  ``backend="kernel"`` runs one fused multigraph Pallas launch
    per lane shard, forward AND backward (custom VJP) — the training path
    of the mesh-scale launcher.

    Equivalence contract (pinned by tests/test_multilane): the FORWARD is
    bit-identical across lane counts and backends — units are (graph,
    dst-block-row) disjoint, so lane assignment only moves exact zeros
    through the scatter/psum.  The BACKWARD's cross-unit reduction
    (d_h_src over all units sharing the src space) is grouped by lane,
    so gradients agree to f32 tolerance (~1e-9) across lane counts and
    are bit-deterministic for a fixed topology.
    """
    x = data.features[data.target_type]
    heads = params["a_src"].shape[1]
    n = x.shape[0]

    h = stages.feature_projection(x, params["w_fp"], params["b_fp"])
    h = shard(h, "act_vertex", "act_feat")  # projected-once FP output (RAB)
    hh = h.reshape(n, heads, -1)

    th_s = jnp.einsum("nhd,ghd->gnh", hh, params["a_src"])
    th_d = jnp.einsum("nhd,ghd->gnh", hh, params["a_dst"])
    n_pad = plan.n_dst_blocks * plan.block  # shared src/dst vertex space
    th_s = _pad_rows(th_s.swapaxes(0, 1), n_pad).swapaxes(0, 1)
    th_d = _pad_rows(th_d.swapaxes(0, 1), n_pad).swapaxes(0, 1)
    hh_p = _pad_rows(hh, n_pad)

    if mesh is None:
        z_all = multilane_na(plan, th_s, th_d, hh_p, backend=backend)
    else:
        z_all = multilane_na_sharded(
            plan, th_s, th_d, hh_p, mesh=mesh, lane_axes=lane_axes, backend=backend
        )
    z_all = z_all[:, :n]  # [G, N, H, Dh]

    z_list, w_list = [], []
    valid_dst = jnp.ones((n,), bool)
    for i in range(len(data.graphs)):
        z = jax.nn.elu(z_all[i].reshape(n, -1))
        z = shard(z, "act_vertex", "act_feat")
        w_p = stages.local_semantic_fusion(
            z, params["w_g"], params["b_g"], params["q"], valid_dst
        )
        z_list.append(z)
        w_list.append(w_p)
    fused, beta = stages.global_semantic_fusion(jnp.stack(w_list), jnp.stack(z_list))
    return shard(fused, "act_vertex", "act_feat"), beta


def han_forward_multilane(
    params,
    data: HGNNData,
    plan: MultiLanePlan,
    *,
    mesh=None,
    lane_axes: tuple[str, ...] = ("lane",),
    backend: str = "reference",
):
    """HAN logits with NA dispatched through a multi-lane plan (see
    ``_han_embed_multilane``)."""
    fused, _ = _han_embed_multilane(
        params, data, plan, mesh=mesh, lane_axes=lane_axes, backend=backend
    )
    return fused @ params["w_out"] + params["b_out"]


# --- staged execution (Fig. 4(a) baseline): one jitted program per stage ---

@functools.partial(jax.jit, static_argnames=())
def _fp_stage(w, b, x):
    return stages.feature_projection(x, w, b)


@jax.jit
def _coeff_stage(h, a_src, a_dst):
    return stages.attention_coefficients(h, a_src, a_dst)


@functools.partial(jax.jit, static_argnames=("num_dst",))
def _na_stage(src, dst, valid, th_s, th_d, h, num_dst):
    z = stages.segment_softmax_aggregate(src, dst, valid, th_s, th_d, h, num_dst)
    return jax.nn.elu(z.reshape(num_dst, -1))


@jax.jit
def _sf_stage(z_stack, w_g, b_g, q, w_out, b_out):
    n = z_stack.shape[1]
    valid = jnp.ones((n,), bool)
    w_list = [
        stages.local_semantic_fusion(z_stack[p], w_g, b_g, q, valid)
        for p in range(z_stack.shape[0])
    ]
    fused, _ = stages.global_semantic_fusion(jnp.stack(w_list), z_stack)
    return fused @ w_out + b_out


def han_forward_staged(params, data: HGNNData):
    """Traditional staged execution: each stage its own program with a host
    barrier after it (`block_until_ready`), mirroring DGL-on-GPU."""
    x = data.features[data.target_type]
    heads = params["a_src"].shape[1]
    h = _fp_stage(params["w_fp"], params["b_fp"], x)
    h.block_until_ready()
    hh = h.reshape(x.shape[0], heads, -1)
    z_list = []
    for i, batch in enumerate(data.graphs):
        th_s, th_d = _coeff_stage(hh, params["a_src"][i], params["a_dst"][i])
        th_s.block_until_ready()
        z = _na_stage(batch.src, batch.dst, batch.valid, th_s, th_d, hh, batch.num_dst)
        z.block_until_ready()
        z_list.append(z)
    out = _sf_stage(
        jnp.stack(z_list), params["w_g"], params["b_g"], params["q"],
        params["w_out"], params["b_out"],
    )
    out.block_until_ready()
    return out


HAN = HGNNModel(name="HAN", init=init_han, forward=han_forward)
