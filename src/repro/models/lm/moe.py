"""Mixture-of-Experts with capacity-based sorted dispatch.

This is where HiHGNN's ideas transfer directly to the assigned MoE archs
(DESIGN.md §5): experts are the semantic graphs — independent parallel
computation fused by a router-weighted combine (GSF analogue).  The
independency-aware multi-lane execution becomes expert parallelism (the
expert dim sharded on the `model` mesh axis), and the paper's overflow
workload (OW) handling becomes the capacity factor: tokens beyond an
expert's capacity are dropped to the residual path, keeping every lane's
workload bounded exactly like the Local Scheduler's threshold.

Dispatch is sort-based per batch row (static shapes, no [B,S,E,C] one-hot
blowup): tokens are ranked within their expert by arrival order and
written into an [E, C] index table; gather -> expert FFN einsum ->
weighted scatter-add back.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...dist.sharding import shard
from .config import LMConfig
from .layers import P


def moe_specs(cfg: LMConfig, *, layers: int | None = None) -> dict:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    lead = () if layers is None else (layers,)
    lax_ = () if layers is None else ("layers",)
    # EP when experts divide the model axis (dbrx 16e); otherwise experts
    # replicate and the FFN dim is tensor-parallel (grok 8e) — DESIGN.md §5
    ex = "experts" if cfg.ep_shard else None
    return {
        "router": P(lead + (d, e), lax_ + ("embed", None)),
        "w_gate": P(lead + (e, d, ff), lax_ + (ex, "embed", "mlp")),
        "w_up": P(lead + (e, d, ff), lax_ + (ex, "embed", "mlp")),
        "w_down": P(lead + (e, ff, d), lax_ + (ex, "mlp", "embed")),
    }


def _capacity(cfg: LMConfig, seq: int) -> int:
    c = int(seq * cfg.experts_per_tok * cfg.moe_capacity_factor / cfg.num_experts)
    return max(8, ((c + 7) // 8) * 8)


def moe_forward(
    params: dict, x: jnp.ndarray, cfg: LMConfig
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x [B, S, D] -> (out [B, S, D], aux_loss scalar).

    Per-row dispatch: each batch row routes its S tokens independently
    (rows are data-parallel, experts model-parallel)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_tok
    cap = _capacity(cfg, s)

    logits = (x @ params["router"]).astype(jnp.float32)  # [B, S, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)  # [B, S, k]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch-style): E * sum_e f_e * p_e
    me = probs.mean(axis=(0, 1))  # [E]
    ce = jax.nn.one_hot(expert_ids, e).sum(axis=2).mean(axis=(0, 1)) / k  # [E]
    aux = e * jnp.sum(me * ce)

    def dispatch_row(ids_row, gates_row, x_row):
        # ids_row [S, k]; x_row [S, D] -> per-expert token tables
        flat_e = ids_row.reshape(-1)  # [S*k]
        flat_tok = jnp.repeat(jnp.arange(s), k)
        flat_gate = gates_row.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        se, st, sg = flat_e[order], flat_tok[order], flat_gate[order]
        # rank of each copy within its expert
        start = jnp.searchsorted(se, jnp.arange(e))  # [E]
        rank = jnp.arange(s * k) - start[se]
        keep = rank < cap
        # index table [E, cap] of token ids (-1 = empty), gate table [E, cap]
        tbl = jnp.full((e, cap), -1, jnp.int32)
        gtbl = jnp.zeros((e, cap), jnp.float32)
        slot_e = jnp.where(keep, se, 0)
        slot_r = jnp.where(keep, rank, 0)
        tok_val = jnp.where(keep, st, -1).astype(jnp.int32)
        gate_val = jnp.where(keep, sg, 0.0)
        # later writes win; padding writes all target (0,0) with -1 only if
        # keep is False there -> guard with max-combine via .add on one-hot-free path
        tbl = tbl.at[slot_e, slot_r].max(tok_val)
        gtbl = gtbl.at[slot_e, slot_r].add(jnp.where(keep, gate_val, 0.0))
        xin = jnp.where((tbl >= 0)[:, :, None], x_row[jnp.maximum(tbl, 0)], 0.0)  # [E, cap, D]
        return xin, tbl, gtbl

    xin, tbl, gtbl = jax.vmap(dispatch_row)(expert_ids, gate_vals, x)  # [B, E, cap, D]
    ex_act = "act_experts" if cfg.ep_shard else None
    xin = shard(xin, "act_batch", ex_act, None, "act_embed")

    dt = x.dtype
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xin, params["w_gate"].astype(dt)))
    h = h * jnp.einsum("becd,edf->becf", xin, params["w_up"].astype(dt))
    h = shard(h, "act_batch", ex_act, None, "act_mlp")
    y = jnp.einsum("becf,efd->becd", h, params["w_down"].astype(dt))  # [B, E, cap, D]
    y = shard(y, "act_batch", ex_act, None, "act_embed")

    def combine_row(y_row, tbl_row, gtbl_row):
        out = jnp.zeros((s, d), y_row.dtype)
        w = jnp.where(tbl_row >= 0, gtbl_row, 0.0).astype(y_row.dtype)
        return out.at[jnp.maximum(tbl_row, 0).reshape(-1)].add(
            (y_row * w[:, :, None]).reshape(-1, d)
        )

    out = jax.vmap(combine_row)(y, tbl, gtbl)
    out = shard(out, "act_batch", None, "act_embed")
    return out.astype(x.dtype), aux.astype(jnp.float32)
