"""Architecture config schema for the assigned LM-family models."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- attention options ---
    rope_theta: float = 1e6
    qkv_bias: bool = False          # qwen2
    qk_norm: bool = False           # qwen3
    m_rope: bool = False            # qwen2-vl multimodal RoPE
    m_rope_sections: tuple[int, ...] = (16, 24, 24)
    window: int | None = None       # local attention width
    logits_soft_cap: float | None = None

    # --- layer pattern ---
    # cycled across layers: "attn" (global), "local" (windowed attn),
    # "rglru" (recurrent), "ssm" (mamba2)
    block_pattern: tuple[str, ...] = ("attn",)

    # --- mlp ---
    mlp_gated: bool = True          # SwiGLU (False -> plain GELU MLP, whisper)
    act: str = "silu"

    # --- moe ---
    num_experts: int = 0
    experts_per_tok: int = 0
    moe_capacity_factor: float = 1.25
    ep_shard: bool = True  # shard experts on `model` (False: TP inside experts)

    # --- ssm (mamba2 SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256

    # --- rglru (recurrentgemma) ---
    rnn_width: int = 0              # 0 -> d_model
    rglru_c: float = 8.0

    # --- enc-dec (whisper) ---
    encoder_layers: int = 0         # >0 -> encoder-decoder
    encoder_seq: int = 1500         # audio frame positions (stub frontend)

    # --- embeddings / precision / memory ---
    tie_embeddings: bool = True
    embed_scale: bool = False       # multiply embeddings by sqrt(d_model)
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"         # activation/compute dtype
    param_dtype: str = "float32"    # stored parameter dtype
    fsdp: bool = False              # shard params/opt-state over the data axis
    remat: str = "none"             # none | full | dots
    subquadratic: bool = False      # supports long_500k decode
    frontend: str | None = None     # "audio" | "vision" stub frontends

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    @property
    def d_inner(self) -> int:  # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def pattern_for_layer(self, i: int) -> str:
        return self.block_pattern[i % len(self.block_pattern)]

    def param_count(self) -> int:
        """Analytic parameter count (embedding + layers), for 6ND roofline."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        n = v * d  # embedding
        if not self.tie_embeddings:
            n += v * d
        per_layer = {}
        qkv = d * (self.num_heads + 2 * self.num_kv_heads) * self.head_dim
        o = self.num_heads * self.head_dim * d
        per_layer["attn"] = qkv + o
        per_layer["local"] = qkv + o
        mlp = d * ff * (3 if self.mlp_gated else 2)
        if self.is_moe:
            mlp = self.num_experts * d * ff * 3 + d * self.num_experts
        di = self.d_inner
        per_layer["ssm"] = d * (2 * di + 2 * self.ssm_state + self.ssm_heads) + di * d + di * self.ssm_conv_width
        rw = self.rnn_width or d
        per_layer["rglru"] = d * rw * 3 + rw * d + 2 * rw * rw + rw * self.ssm_conv_width
        total_layers = 0
        for i in range(self.num_layers):
            pat = self.pattern_for_layer(i)
            blk = per_layer.get(pat, per_layer["attn"])
            if pat in ("attn", "local", "rglru"):  # these blocks carry an MLP
                blk += mlp
            total_layers += blk
        n += total_layers
        if self.is_encoder_decoder:
            enc = self.encoder_layers * (per_layer["attn"] + d * ff * 2)
            xattn = self.num_layers * (qkv + o)
            n += enc + xattn
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts) for 6·N_act·D."""
        if not self.is_moe:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        dense_moe = self.num_experts * d * ff * 3
        active_moe = self.experts_per_tok * d * ff * 3
        return self.param_count() - self.num_layers * (dense_moe - active_moe)
