"""Composable decoder stack for the assigned architectures.

Layers are grouped into *superblocks* (one period of cfg.block_pattern)
and scanned with `jax.lax.scan` so the compiled HLO stays O(1) in depth —
essential for compiling 64-layer 314B configs in the dry-run.  Remainder
layers (pattern not dividing num_layers, e.g. recurrentgemma's 38 = 12×3+2)
run unrolled after the scan.

Every forward mode shares the block implementations:
  * forward()      — full sequence (training / prefill), returns logits
  * decode_step()  — one token against carried caches (serving)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from ...dist.sharding import shard
from .attention import (
    AttnCache,
    attention_decode,
    attention_forward,
    attention_specs,
    init_attn_cache,
)
from .config import LMConfig
from .layers import P, init_from_specs, axes_from_specs, mrope_angles, rms_norm, rope_angles
from .mlp import mlp_forward, mlp_specs
from .moe import moe_forward, moe_specs
from .rglru import init_rglru_cache, rglru_decode, rglru_forward, rglru_specs
from .ssm import init_ssm_cache, ssm_decode, ssm_forward, ssm_specs


def vocab_padded(cfg: LMConfig) -> int:
    return ((cfg.vocab_size + 255) // 256) * 256


def _block_specs(cfg: LMConfig, pat: str, layers: int | None) -> dict:
    d = cfg.d_model
    lead = () if layers is None else (layers,)
    lx = () if layers is None else ("layers",)
    norm = lambda: P(lead + (d,), lx + (None,), init="ones")
    if pat in ("attn", "local"):
        mixer = {"norm1": norm(), "attn": attention_specs(cfg, layers=layers)}
        if cfg.is_moe:
            mixer.update(norm2=norm(), moe=moe_specs(cfg, layers=layers))
        else:
            mixer.update(norm2=norm(), mlp=mlp_specs(cfg, layers=layers))
        return mixer
    if pat == "ssm":
        return {"norm1": norm(), "ssm": ssm_specs(cfg, layers=layers)}
    if pat == "rglru":
        return {
            "norm1": norm(),
            "rglru": rglru_specs(cfg, layers=layers),
            "norm2": norm(),
            "mlp": mlp_specs(cfg, layers=layers),
        }
    raise ValueError(pat)


def _layout(cfg: LMConfig) -> tuple[int, int]:
    period = len(cfg.block_pattern)
    return cfg.num_layers // period, cfg.num_layers % period


def decoder_specs(cfg: LMConfig) -> dict:
    n_super, rem = _layout(cfg)
    vp = vocab_padded(cfg)
    specs: dict[str, Any] = {
        "embed": P((vp, cfg.d_model), ("vocab", "embed"), scale=0.02),
        "final_norm": P((cfg.d_model,), (None,), init="ones"),
    }
    if n_super > 0:
        specs["scan"] = {
            f"pos{i}": _block_specs(cfg, pat, n_super)
            for i, pat in enumerate(cfg.block_pattern)
        }
    if rem:
        specs["tail"] = [
            _block_specs(cfg, cfg.block_pattern[i], None) for i in range(rem)
        ]
    if not cfg.tie_embeddings:
        specs["lm_head"] = P((cfg.d_model, vp), ("embed", "vocab"), scale=0.02)
    return specs


def init_decoder(cfg: LMConfig, rng: jax.Array):
    dtype = jnp.dtype(cfg.param_dtype)
    return init_from_specs(decoder_specs(cfg), rng, dtype)


def decoder_axes(cfg: LMConfig):
    return axes_from_specs(decoder_specs(cfg))


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _angles(cfg: LMConfig, positions: jnp.ndarray) -> jnp.ndarray | None:
    if cfg.m_rope:
        return mrope_angles(positions, cfg.head_dim, cfg.rope_theta, cfg.m_rope_sections)
    return rope_angles(positions, cfg.head_dim, cfg.rope_theta)


def _block_forward(cfg: LMConfig, pat: str, p: dict, h: jnp.ndarray, angles, impl: str):
    """One block, full-sequence.  Returns (h, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if pat in ("attn", "local"):
        win = cfg.window if pat == "local" else None
        a = attention_forward(
            p["attn"], rms_norm(h, p["norm1"], cfg.norm_eps), cfg,
            angles=angles, window=win, impl=impl,
        )
        h = h + a
        x = rms_norm(h, p["norm2"], cfg.norm_eps)
        if cfg.is_moe:
            m, aux = moe_forward(p["moe"], x, cfg)
        else:
            m = mlp_forward(p["mlp"], x, cfg)
        h = h + m
    elif pat == "ssm":
        y, _ = ssm_forward(p["ssm"], rms_norm(h, p["norm1"], cfg.norm_eps), cfg)
        h = h + y
    elif pat == "rglru":
        y, _ = rglru_forward(p["rglru"], rms_norm(h, p["norm1"], cfg.norm_eps), cfg)
        h = h + y
        h = h + mlp_forward(p["mlp"], rms_norm(h, p["norm2"], cfg.norm_eps), cfg)
    h = shard(h, "act_batch", "act_seq", "act_embed")
    return h, aux


def _block_decode(cfg: LMConfig, pat: str, p: dict, h, angles, cache, cache_pos):
    """One block, single token.  cache is pattern-specific; returns new cache."""
    if pat in ("attn", "local"):
        win = cfg.window if pat == "local" else None
        a, cache_a = attention_decode(
            p["attn"], rms_norm(h, p["norm1"], cfg.norm_eps), cfg,
            cache, cache_pos, angles=angles, window=win,
        )
        h = h + a
        x = rms_norm(h, p["norm2"], cfg.norm_eps)
        if cfg.is_moe:
            m, _ = moe_forward(p["moe"], x, cfg)
        else:
            m = mlp_forward(p["mlp"], x, cfg)
        return h + m, cache_a
    if pat == "ssm":
        conv, ssd = cache
        y, (conv, ssd) = ssm_decode(p["ssm"], rms_norm(h, p["norm1"], cfg.norm_eps), cfg, conv, ssd)
        return h + y, (conv, ssd)
    if pat == "rglru":
        conv, hs = cache
        y, (conv, hs) = rglru_decode(p["rglru"], rms_norm(h, p["norm1"], cfg.norm_eps), cfg, conv, hs)
        h = h + y
        h = h + mlp_forward(p["mlp"], rms_norm(h, p["norm2"], cfg.norm_eps), cfg)
        return h, (conv, hs)
    raise ValueError(pat)


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def embed_tokens(params, cfg: LMConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    if cfg.embed_scale:
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    return h


def logits_from_hidden(params, cfg: LMConfig, h: jnp.ndarray) -> jnp.ndarray:
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = h @ params["embed"].T.astype(h.dtype)
    else:
        logits = h @ params["lm_head"].astype(h.dtype)
    return shard(logits, "act_batch", "act_seq", "act_vocab")


def forward(
    params: dict,
    cfg: LMConfig,
    tokens: jnp.ndarray,                 # [B, S] int32
    *,
    positions: jnp.ndarray | None = None,  # [B, S] or [B, S, 3] (m_rope)
    visual_embeds: jnp.ndarray | None = None,  # [B, n_vis, D] stub frontend output
    impl: str = "xla",
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (logits [B, S, vocab_padded], aux_loss)."""
    b, s = tokens.shape
    if positions is None:
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        positions = (
            jnp.broadcast_to(pos[..., None], (b, s, 3)) if cfg.m_rope else pos
        )
    angles = _angles(cfg, positions)

    h = embed_tokens(params, cfg, tokens)
    if visual_embeds is not None:
        # stub modality frontend: precomputed patch/frame embeddings occupy
        # the first n_vis slots (input_specs provides them per the brief)
        nv = visual_embeds.shape[1]
        h = jnp.concatenate([visual_embeds.astype(h.dtype), h[:, nv:]], axis=1)
    h = shard(h, "act_batch", "act_seq", "act_embed")

    n_super, rem = _layout(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    if n_super > 0:
        def superblock(carry, sp):
            hh, aux = carry
            for i, pat in enumerate(cfg.block_pattern):
                hh, a = _block_forward(cfg, pat, sp[f"pos{i}"], hh, angles, impl)
                aux = aux + a
            return (hh, aux), None

        if cfg.remat == "full":
            superblock = jax.checkpoint(
                superblock, policy=jax.checkpoint_policies.nothing_saveable
            )
        elif cfg.remat == "dots":
            superblock = jax.checkpoint(
                superblock,
                policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
            )
        (h, aux_total), _ = jax.lax.scan(superblock, (h, aux_total), params["scan"])
    for i in range(rem):
        h, a = _block_forward(
            cfg, cfg.block_pattern[i], params["tail"][i], h, angles, impl
        )
        aux_total = aux_total + a
    return logits_from_hidden(params, cfg, h), aux_total


# ---------------------------------------------------------------------------
# Decode (serving)
# ---------------------------------------------------------------------------

def _cache_for(cfg: LMConfig, pat: str, batch: int, cache_len: int, dtype):
    if pat in ("attn", "local"):
        eff_cfg = cfg if pat == "attn" else dataclasses.replace(cfg, window=cfg.window)
        c = init_attn_cache(eff_cfg, batch, cache_len, dtype)
        if pat == "attn":
            c = AttnCache(
                k=jnp.zeros((batch, cache_len, cfg.num_kv_heads, cfg.head_dim), dtype),
                v=jnp.zeros((batch, cache_len, cfg.num_kv_heads, cfg.head_dim), dtype),
                pos=jnp.full((batch, cache_len), -1, jnp.int32),
            )
        return c
    if pat == "ssm":
        return init_ssm_cache(cfg, batch, dtype)
    if pat == "rglru":
        return init_rglru_cache(cfg, batch, dtype)
    raise ValueError(pat)


def init_caches(cfg: LMConfig, batch: int, cache_len: int, dtype=jnp.bfloat16):
    """Stacked caches matching the scan layout + tail list."""
    n_super, rem = _layout(cfg)
    caches: dict[str, Any] = {}
    if n_super > 0:
        caches["scan"] = {
            f"pos{i}": jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x[None], (n_super,) + x.shape),
                _cache_for(cfg, pat, batch, cache_len, dtype),
            )
            for i, pat in enumerate(cfg.block_pattern)
        }
    if rem:
        caches["tail"] = [
            _cache_for(cfg, cfg.block_pattern[i], batch, cache_len, dtype)
            for i in range(rem)
        ]
    return caches


def mark_cache_filled(caches, cache_pos: int):
    """Mark attention cache slots [0, cache_pos) as holding real history —
    used to lower decode-with-full-cache without running a real prefill."""
    def fix(x):
        if isinstance(x, AttnCache):
            n = x.pos.shape[-1]
            pos = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), x.pos.shape)
            pos = jnp.where(pos < cache_pos, pos, -1)
            return AttnCache(k=x.k, v=x.v, pos=pos)
        return x

    return jax.tree_util.tree_map(fix, caches, is_leaf=lambda x: isinstance(x, AttnCache))


def decode_step(
    params: dict,
    cfg: LMConfig,
    tokens: jnp.ndarray,      # [B, 1]
    cache_pos: jnp.ndarray,   # int32 scalar, or [B] per-slot positions
    caches,
) -> tuple[jnp.ndarray, Any]:
    """One decode step: returns (logits [B, 1, vocab_padded], new caches)."""
    b = tokens.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(cache_pos, jnp.int32), (b,))[:, None]
    positions = jnp.broadcast_to(pos[..., None], (b, 1, 3)) if cfg.m_rope else pos
    angles = _angles(cfg, positions)

    h = embed_tokens(params, cfg, tokens)
    n_super, rem = _layout(cfg)
    new_caches: dict[str, Any] = {}
    if n_super > 0:
        def superblock(hh, xs):
            sp, sc = xs
            out_caches = {}
            for i, pat in enumerate(cfg.block_pattern):
                hh, nc = _block_decode(
                    cfg, pat, sp[f"pos{i}"], hh, angles, sc[f"pos{i}"], cache_pos
                )
                out_caches[f"pos{i}"] = nc
            return hh, out_caches

        h, new_scan = jax.lax.scan(superblock, h, (params["scan"], caches["scan"]))
        new_caches["scan"] = new_scan
    if rem:
        tail = []
        for i in range(rem):
            h, nc = _block_decode(
                cfg, cfg.block_pattern[i], params["tail"][i], h, angles,
                caches["tail"][i], cache_pos,
            )
            tail.append(nc)
        new_caches["tail"] = tail
    return logits_from_hidden(params, cfg, h), new_caches
