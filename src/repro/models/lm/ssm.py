"""Mamba2 — State Space Duality (SSD) block (Dao & Gu, 2024).

Chunked SSD: within a chunk the recurrence is computed as masked
attention-like matmuls (MXU-friendly); across chunks a small state
[H, P, N] is carried by a scan.  The same structure HiHGNN exploits —
compute-bound intra-block work fused with a cheap sequential carry — and
the reason this arch supports ``long_500k``: decode state is O(1) in
context length.

Shapes: d_inner = expand*d_model, P = head_dim, H = d_inner/P, N = state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...dist.sharding import shard
from .config import LMConfig
from .layers import P, rms_norm


def ssm_specs(cfg: LMConfig, *, layers: int | None = None) -> dict:
    d = cfg.d_model
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_ch = di + 2 * n
    lead = () if layers is None else (layers,)
    lx = () if layers is None else ("layers",)
    return {
        # in_proj emits (z, x, B, C, dt)
        "w_in": P(lead + (d, 2 * di + 2 * n + h), lx + ("embed", "ssm_inner")),
        "conv_w": P(lead + (cfg.ssm_conv_width, conv_ch), lx + (None, "ssm_inner"), scale=0.3),
        "conv_b": P(lead + (conv_ch,), lx + ("ssm_inner",), init="zeros"),
        "a_log": P(lead + (h,), lx + (None,), init="zeros"),
        "dt_bias": P(lead + (h,), lx + (None,), init="zeros"),
        "d_skip": P(lead + (h,), lx + (None,), init="ones"),
        "norm": P(lead + (di,), lx + ("ssm_inner",), init="ones"),
        "w_out": P(lead + (di, d), lx + ("ssm_inner", "embed")),
    }


def _split_in(params, x, cfg: LMConfig):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    zxbcdt = x @ params["w_in"].astype(x.dtype)
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)
    return z, xbc, dt  # dt [..., H]


def _causal_conv(xbc, conv_w, conv_b, state=None):
    """Depthwise causal conv1d.  xbc [B, S, C]; conv_w [W, C].

    state [B, W-1, C] holds the trailing inputs from the previous segment
    (None = zero history).  Returns (out [B,S,C], new_state)."""
    w = conv_w.shape[0]
    b = xbc.shape[0]
    if state is None:
        state = jnp.zeros((b, w - 1, xbc.shape[-1]), xbc.dtype)
    padded = jnp.concatenate([state, xbc], axis=1)
    out = sum(
        padded[:, i : i + xbc.shape[1], :] * conv_w[i].astype(xbc.dtype)
        for i in range(w)
    )
    new_state = padded[:, -(w - 1) :, :]
    return jax.nn.silu(out + conv_b.astype(xbc.dtype)), new_state


def _ssd_chunked(xh, dt, a, bmat, cmat, chunk: int, init_state=None):
    """SSD scan.  xh [B,S,H,P]; dt [B,S,H] (post-softplus); a [H] (<0);
    bmat/cmat [B,S,N].  Returns (y [B,S,H,P], final_state [B,H,P,N])."""
    b, s, h, p = xh.shape
    n = bmat.shape[-1]
    nc = s // chunk
    assert nc * chunk == s, (s, chunk)

    lam = dt * a  # [B,S,H] log-decay per step (negative)
    xdt = xh * dt[..., None]  # dt-weighted inputs

    def resh(t):
        return t.reshape((b, nc, chunk) + t.shape[2:])

    lam_c, xdt_c, b_c, c_c = resh(lam), resh(xdt), resh(bmat), resh(cmat)
    cum = jnp.cumsum(lam_c, axis=2)  # [B,nc,L,H] inclusive log-decay

    # intra-chunk (dual/attention form): G[t,s'] = C_t·B_s' * exp(cum_t - cum_s'), s'<=t
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [B,nc,L,L,H]
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    cb = jnp.einsum("bctn,bcsn->bcts", c_c, b_c)  # [B,nc,L,L]
    y_intra = jnp.einsum("bcts,bctsh,bcshp->bcthp", cb, decay, xdt_c)

    # per-chunk outgoing state: sum_s exp(cum_last - cum_s) * B_s ⊗ xdt_s
    decay_out = jnp.exp(cum[:, :, -1:, :] - cum)  # [B,nc,L,H]
    states = jnp.einsum("bcsh,bcsn,bcshp->bchpn", decay_out, b_c, xdt_c)

    # inter-chunk scan over the carried state
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # [B,nc,H]
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), xh.dtype)

    def step(carry, inp):
        dec, st_new = inp  # [B,H], [B,H,P,N]
        out_carry = carry * dec[:, :, None, None] + st_new
        return out_carry, carry  # emit the state *entering* this chunk

    final_state, entry_states = jax.lax.scan(
        step,
        init_state,
        (chunk_decay.transpose(1, 0, 2), states.transpose(1, 0, 2, 3, 4)),
    )
    entry_states = entry_states.transpose(1, 0, 2, 3, 4)  # [B,nc,H,P,N]

    # inter-chunk contribution: C_t · (entry_state decayed to t)
    decay_in = jnp.exp(cum)  # [B,nc,L,H]
    y_inter = jnp.einsum("bctn,bchpn,bcth->bcthp", c_c, entry_states, decay_in)

    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y, final_state


def ssm_forward(params, x: jnp.ndarray, cfg: LMConfig, conv_state=None, ssd_state=None):
    """Full-sequence mamba2 block.  x [B,S,D] -> (y, (conv_state, ssd_state))."""
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    p = cfg.ssm_head_dim
    z, xbc, dt = _split_in(params, x, cfg)
    xbc, conv_state = _causal_conv(xbc, params["conv_w"], params["conv_b"], conv_state)
    xi, bmat, cmat = jnp.split(xbc, [di, di + n], axis=-1)
    xh = xi.reshape(x.shape[0], x.shape[1], h, p)
    xh = shard(xh, "act_batch", None, "act_heads", None)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["a_log"].astype(jnp.float32))  # [H] < 0
    chunk = min(cfg.ssm_chunk, x.shape[1])
    while x.shape[1] % chunk:  # chunk must divide the sequence length
        chunk -= 1
    y, ssd_state = _ssd_chunked(
        xh.astype(jnp.float32), dt, a, bmat.astype(jnp.float32), cmat.astype(jnp.float32),
        chunk, ssd_state,
    )
    y = y + params["d_skip"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(x.shape[0], x.shape[1], di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    return y @ params["w_out"].astype(x.dtype), (conv_state, ssd_state)


def ssm_decode(params, x: jnp.ndarray, cfg: LMConfig, conv_state, ssd_state):
    """Single-token decode.  x [B,1,D]; states carried O(1) in context."""
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    p = cfg.ssm_head_dim
    b = x.shape[0]
    z, xbc, dt = _split_in(params, x, cfg)
    xbc, conv_state = _causal_conv(xbc, params["conv_w"], params["conv_b"], conv_state)
    xi, bmat, cmat = jnp.split(xbc[:, 0], [di, di + n], axis=-1)
    xh = xi.reshape(b, h, p).astype(jnp.float32)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))  # [B,H]
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt * a)  # [B,H]
    upd = jnp.einsum("bhp,bn->bhpn", xh * dt[..., None], bmat.astype(jnp.float32))
    ssd_state = ssd_state * decay[:, :, None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", cmat.astype(jnp.float32), ssd_state)
    y = y + params["d_skip"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    return y @ params["w_out"].astype(x.dtype), (conv_state, ssd_state)


def init_ssm_cache(cfg: LMConfig, batch: int, dtype):
    conv_ch = cfg.d_inner + 2 * cfg.ssm_state
    conv = jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_ch), dtype)
    ssd = jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32)
    return conv, ssd
