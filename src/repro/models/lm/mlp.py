"""Dense MLP blocks (SwiGLU / GELU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...dist.sharding import shard
from .config import LMConfig
from .layers import P


def mlp_specs(cfg: LMConfig, *, layers: int | None = None) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    lead = () if layers is None else (layers,)
    lax = () if layers is None else ("layers",)
    if cfg.mlp_gated:
        return {
            "w_gate": P(lead + (d, ff), lax + ("embed", "mlp")),
            "w_up": P(lead + (d, ff), lax + ("embed", "mlp")),
            "w_down": P(lead + (ff, d), lax + ("mlp", "embed")),
        }
    return {
        "w_up": P(lead + (d, ff), lax + ("embed", "mlp")),
        "b_up": P(lead + (ff,), lax + ("mlp",), init="zeros"),
        "w_down": P(lead + (ff, d), lax + ("mlp", "embed")),
        "b_down": P(lead + (d,), lax + (None,), init="zeros"),
    }


def _act(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": jax.nn.gelu,
        "relu": jax.nn.relu,
        "relu2": lambda x: jnp.square(jax.nn.relu(x)),  # nemotron/minitron
    }[name]


def mlp_forward(params: dict, x: jnp.ndarray, cfg: LMConfig) -> jnp.ndarray:
    """x [B, S, D] -> [B, S, D]."""
    act = _act(cfg.act)
    dt = x.dtype
    if cfg.mlp_gated:
        h = act(x @ params["w_gate"].astype(dt)) * (x @ params["w_up"].astype(dt))
        h = shard(h, "act_batch", None, "act_mlp")
        return h @ params["w_down"].astype(dt)
    h = act(x @ params["w_up"].astype(dt) + params["b_up"].astype(dt))
    h = shard(h, "act_batch", None, "act_mlp")
    return h @ params["w_down"].astype(dt) + params["b_down"].astype(dt)
