"""Unified model API: one object per architecture family that launch/,
train/ and serve/ drive without knowing the family internals."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import encdec, transformer
from .config import LMConfig


@dataclasses.dataclass(frozen=True)
class LMApi:
    cfg: LMConfig
    init: Callable[[jax.Array], Any]
    axes: Callable[[], Any]
    # forward(params, tokens, **kw) -> (logits, aux)
    forward: Callable[..., tuple[jnp.ndarray, jnp.ndarray]]
    # decode(params, tokens, cache_pos, caches, **kw) -> (logits, caches)
    decode: Callable[..., tuple[jnp.ndarray, Any]]
    init_caches: Callable[..., Any]

    @property
    def name(self) -> str:
        return self.cfg.name


def build(cfg: LMConfig) -> LMApi:
    if cfg.is_encoder_decoder:
        def fwd(params, tokens, **kw):
            return encdec.forward(params, cfg, tokens, **kw)

        def dec(params, tokens, cache_pos, caches, **kw):
            cross = kw.pop("cross_kv")
            return encdec.decode_step(params, cfg, tokens, cache_pos, caches, cross)

        return LMApi(
            cfg=cfg,
            init=lambda rng: encdec.init_encdec(cfg, rng),
            axes=lambda: encdec.encdec_axes(cfg),
            forward=fwd,
            decode=dec,
            init_caches=lambda batch, cache_len, dtype=jnp.bfloat16: encdec.init_encdec_caches(
                cfg, batch, cache_len, dtype
            ),
        )

    def fwd(params, tokens, **kw):
        return transformer.forward(params, cfg, tokens, **kw)

    def dec(params, tokens, cache_pos, caches, **kw):
        return transformer.decode_step(params, cfg, tokens, cache_pos, caches)

    return LMApi(
        cfg=cfg,
        init=lambda rng: transformer.init_decoder(cfg, rng),
        axes=lambda: transformer.decoder_axes(cfg),
        forward=fwd,
        decode=dec,
        init_caches=lambda batch, cache_len, dtype=jnp.bfloat16: transformer.init_caches(
            cfg, batch, cache_len, dtype
        ),
    )
