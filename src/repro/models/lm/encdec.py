"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

Per the brief, [audio] entries specify the transformer backbone only: the
conv/mel frontend is a stub — ``input_specs()`` supplies precomputed frame
embeddings [B, encoder_seq, d_model].  Architecture: pre-LN MHA encoder
(bidirectional) + decoder with causal self-attention, cross-attention to
the encoder output, GELU MLPs, learned positions, untied LM head
(following whisper-large-v3: 32 enc + 32 dec layers, d=1280, 20 heads).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ...dist.sharding import shard
from .attention import (
    attention_decode,
    attention_forward,
    attention_specs,
    cross_attention_forward,
    encode_cross_kv,
    init_attn_cache,
)
from .config import LMConfig
from .layers import P, axes_from_specs, init_from_specs, layer_norm, sinusoidal_positions
from .mlp import mlp_forward, mlp_specs
from .transformer import vocab_padded


def _norm_specs(layers, d):
    lead = () if layers is None else (layers,)
    lx = () if layers is None else ("layers",)
    return {
        "scale": P(lead + (d,), lx + (None,), init="ones"),
        "bias": P(lead + (d,), lx + (None,), init="zeros"),
    }


def encdec_specs(cfg: LMConfig) -> dict:
    d = cfg.d_model
    vp = vocab_padded(cfg)
    le, ld = cfg.encoder_layers, cfg.num_layers
    enc_block = {
        "norm1": _norm_specs(le, d),
        "attn": attention_specs(cfg, layers=le),
        "norm2": _norm_specs(le, d),
        "mlp": mlp_specs(cfg, layers=le),
    }
    dec_block = {
        "norm1": _norm_specs(ld, d),
        "self_attn": attention_specs(cfg, layers=ld),
        "norm_x": _norm_specs(ld, d),
        "cross_attn": attention_specs(cfg, layers=ld, cross=True),
        "norm2": _norm_specs(ld, d),
        "mlp": mlp_specs(cfg, layers=ld),
    }
    return {
        "embed": P((vp, d), ("vocab", "embed"), scale=0.02),
        # whisper's real decoder context is 448; the assigned decode_32k
        # shape demands 32768 positions — mechanically extended (DESIGN §5)
        "dec_pos": P((32768, d), (None, "embed"), scale=0.01),
        "encoder": enc_block,
        "enc_final": _norm_specs(None, d),
        "decoder": dec_block,
        "dec_final": _norm_specs(None, d),
    }


def init_encdec(cfg: LMConfig, rng: jax.Array):
    return init_from_specs(encdec_specs(cfg), rng, jnp.dtype(cfg.param_dtype))


def encdec_axes(cfg: LMConfig):
    return axes_from_specs(encdec_specs(cfg))


def _ln(p, x, eps=1e-5):
    return layer_norm(x, p["scale"].astype(jnp.float32), p["bias"].astype(jnp.float32), eps)


def _maybe_remat(fn, cfg: LMConfig):
    if cfg.remat == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return fn


def encode(params, cfg: LMConfig, frames: jnp.ndarray, *, impl: str = "xla") -> jnp.ndarray:
    """frames [B, S_enc, D] (stub frontend output) -> encoder states."""
    b, s, d = frames.shape
    h = frames + jnp.asarray(sinusoidal_positions(s, d))[None].astype(frames.dtype)
    h = shard(h, "act_batch", "act_seq", "act_embed")

    def block(hh, p):
        a = attention_forward(
            p["attn"], _ln(p["norm1"], hh).astype(hh.dtype), cfg,
            angles=None, causal=False, impl=impl,
        )
        hh = hh + a
        hh = hh + mlp_forward(p["mlp"], _ln(p["norm2"], hh).astype(hh.dtype), cfg)
        return shard(hh, "act_batch", "act_seq", "act_embed"), None

    h, _ = jax.lax.scan(_maybe_remat(block, cfg), h, params["encoder"])
    return _ln(params["enc_final"], h).astype(h.dtype)


def decode_train(
    params, cfg: LMConfig, tokens: jnp.ndarray, enc_out: jnp.ndarray, *, impl: str = "xla"
) -> jnp.ndarray:
    """Teacher-forced decoder pass -> logits [B, S, vocab_padded]."""
    b, s = tokens.shape
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    h = h + params["dec_pos"][:s][None].astype(h.dtype)
    h = shard(h, "act_batch", "act_seq", "act_embed")

    def block(hh, p):
        a = attention_forward(
            p["self_attn"], _ln(p["norm1"], hh).astype(hh.dtype), cfg,
            angles=None, causal=True, impl=impl,
        )
        hh = hh + a
        kv = encode_cross_kv(p["cross_attn"], enc_out, cfg)
        hh = hh + cross_attention_forward(
            p["cross_attn"], _ln(p["norm_x"], hh).astype(hh.dtype), kv, cfg
        )
        hh = hh + mlp_forward(p["mlp"], _ln(p["norm2"], hh).astype(hh.dtype), cfg)
        return shard(hh, "act_batch", "act_seq", "act_embed"), None

    h, _ = jax.lax.scan(_maybe_remat(block, cfg), h, params["decoder"])
    h = _ln(params["dec_final"], h).astype(h.dtype)
    logits = h @ params["embed"].T.astype(h.dtype)
    return shard(logits, "act_batch", "act_seq", "act_vocab")


def forward(params, cfg: LMConfig, tokens, *, frames=None, impl: str = "xla"):
    """Full enc-dec pass.  frames default: zeros (stub)."""
    b = tokens.shape[0]
    if frames is None:
        frames = jnp.zeros((b, cfg.encoder_seq, cfg.d_model), cfg.dtype)
    enc_out = encode(params, cfg, frames, impl=impl)
    logits = decode_train(params, cfg, tokens, enc_out, impl=impl)
    return logits, jnp.zeros((), jnp.float32)


def init_encdec_caches(cfg: LMConfig, batch: int, cache_len: int, dtype=jnp.bfloat16):
    """Self-attn caches per decoder layer (stacked) + cross-KV recomputed at
    session start (precompute_cross)."""
    c = init_attn_cache(cfg, batch, cache_len, dtype)
    stack = lambda x: jnp.broadcast_to(x[None], (cfg.num_layers,) + x.shape)
    return jax.tree_util.tree_map(stack, c)


def precompute_cross(params, cfg: LMConfig, enc_out: jnp.ndarray):
    def per_layer(p):
        return encode_cross_kv(p, enc_out, cfg)

    return jax.lax.map(per_layer, params["decoder"]["cross_attn"])


def decode_step(params, cfg: LMConfig, tokens, cache_pos, caches, cross_kv):
    """One decoder token.  caches: stacked self-attn caches; cross_kv:
    stacked (k, v) from precompute_cross.  ``cache_pos`` may be a [B]
    vector of per-slot positions (continuous batching)."""
    b = tokens.shape[0]
    cp = jnp.broadcast_to(jnp.asarray(cache_pos, jnp.int32), (b,))
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    h = h + jnp.take(params["dec_pos"], cp, axis=0)[:, None].astype(h.dtype)

    def block(hh, xs):
        p, cache, ckv = xs
        a, new_cache = attention_decode(
            p["self_attn"], _ln(p["norm1"], hh).astype(hh.dtype), cfg,
            cache, cache_pos, angles=None,
        )
        hh = hh + a
        hh = hh + cross_attention_forward(
            p["cross_attn"], _ln(p["norm_x"], hh).astype(hh.dtype), ckv, cfg
        )
        hh = hh + mlp_forward(p["mlp"], _ln(p["norm2"], hh).astype(hh.dtype), cfg)
        return hh, new_cache

    h, new_caches = jax.lax.scan(block, h, (params["decoder"], caches, cross_kv))
    h = _ln(params["dec_final"], h).astype(h.dtype)
    logits = h @ params["embed"].T.astype(h.dtype)
    return logits, new_caches
