"""Shared LM building blocks: param-spec machinery, norms, RoPE/M-RoPE."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Spec-driven parameters: one source of truth for shape + logical axes.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class P:
    """Parameter spec: shape, logical sharding axes, initializer."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"  # normal | zeros | ones
    scale: float | None = None  # stddev; default fan-in

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x: Any) -> bool:
    return isinstance(x, P)


def init_from_specs(specs, rng: jax.Array, param_dtype=jnp.float32):
    leaves, treedef = jax.tree_util.tree_flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(rng, len(leaves))

    def mk(spec: P, key):
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, param_dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, param_dtype)
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        std = spec.scale if spec.scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(param_dtype)

    return jax.tree_util.tree_unflatten(treedef, [mk(s, k) for s, k in zip(leaves, keys)])


def axes_from_specs(specs):
    return jax.tree_util.tree_map(lambda s: s.axes, specs, is_leaf=is_spec)


def abstract_from_specs(specs, param_dtype=jnp.float32):
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, param_dtype), specs, is_leaf=is_spec
    )


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps) * scale + bias).astype(dt)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------

def rope_angles(positions: jnp.ndarray, head_dim: int, theta: float) -> jnp.ndarray:
    """positions [..., S] -> angles [..., S, head_dim//2] (fp32)."""
    half = head_dim // 2
    inv_freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    return positions.astype(jnp.float32)[..., None] * inv_freq


def mrope_angles(
    positions: jnp.ndarray,  # [..., S, 3] (t, h, w)
    head_dim: int,
    theta: float,
    sections: tuple[int, ...],
) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE: frequency slots are partitioned into
    (temporal, height, width) sections, each driven by its own position
    component.  Text tokens carry t == h == w, reducing to plain RoPE."""
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    inv_freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    sec_id = jnp.asarray(
        np.repeat(np.arange(len(sections)), np.asarray(sections)), jnp.int32
    )  # [half] -> which component
    pos = jnp.take_along_axis(
        positions.astype(jnp.float32),
        jnp.broadcast_to(sec_id, positions.shape[:-1] + (half,)).astype(jnp.int32),
        axis=-1,
    )  # [..., S, half]
    return pos * inv_freq


def apply_rope(x: jnp.ndarray, angles: jnp.ndarray) -> jnp.ndarray:
    """x [B, S, H, Dh]; angles [B, S, Dh//2] -> rotated x (llama-style
    rotate-half layout)."""
    half = x.shape[-1] // 2
    cos = jnp.cos(angles)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(angles)[:, :, None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def sinusoidal_positions(n: int, d: int) -> np.ndarray:
    """Whisper-style fixed positional embeddings [n, d]."""
    pos = np.arange(n)[:, None]
    idx = np.arange(d // 2)[None, :]
    angle = pos / (10000 ** (idx / max(d // 2 - 1, 1)))
    out = np.concatenate([np.sin(angle), np.cos(angle)], axis=1)
    return out.astype(np.float32)
