"""RG-LRU recurrent block (RecurrentGemma / Griffin, De et al. 2024).

Real-Gated Linear Recurrent Unit:
    r_t = sigmoid(W_a x_t + b_a)              (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)              (input gate)
    log a_t = -c * softplus(Lambda) * r_t     (diagonal decay, a_t in (0,1))
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t ⊙ x_t)

The linear recurrence is computed with `jax.lax.associative_scan` for
training/prefill (log-depth parallel — the TPU-native answer to a
sequential RNN) and as an O(1) step for decode, which is what makes
``long_500k`` runnable for this hybrid architecture.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import LMConfig
from .layers import P


def rglru_specs(cfg: LMConfig, *, layers: int | None = None) -> dict:
    d = cfg.d_model
    rw = cfg.rnn_width or d
    lead = () if layers is None else (layers,)
    lx = () if layers is None else ("layers",)
    return {
        "w_x": P(lead + (d, rw), lx + ("embed", "rnn")),       # recurrent branch in
        "w_y": P(lead + (d, rw), lx + ("embed", "rnn")),       # gate branch in
        "conv_w": P(lead + (cfg.ssm_conv_width, rw), lx + (None, "rnn"), scale=0.3),
        "conv_b": P(lead + (rw,), lx + ("rnn",), init="zeros"),
        "w_a": P(lead + (rw, rw), lx + ("rnn", None), scale=0.01),
        "b_a": P(lead + (rw,), lx + ("rnn",), init="zeros"),
        "w_i": P(lead + (rw, rw), lx + ("rnn", None), scale=0.01),
        "b_i": P(lead + (rw,), lx + ("rnn",), init="zeros"),
        "lam": P(lead + (rw,), lx + ("rnn",), init="ones"),    # Lambda
        "w_out": P(lead + (rw, d), lx + ("rnn", "embed")),
    }


def _gates(params, u, cfg: LMConfig):
    """u [.., rw] (post-conv) -> (log_a, gated input) in fp32."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ params["w_a"].astype(jnp.float32) + params["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(uf @ params["w_i"].astype(jnp.float32) + params["b_i"].astype(jnp.float32))
    log_a = -cfg.rglru_c * jax.nn.softplus(params["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-9)) * (i * uf)
    return a, b


def _conv(params, u, state):
    w = params["conv_w"].shape[0]
    if state is None:
        state = jnp.zeros((u.shape[0], w - 1, u.shape[-1]), u.dtype)
    padded = jnp.concatenate([state, u], axis=1)
    out = sum(
        padded[:, i : i + u.shape[1], :] * params["conv_w"][i].astype(u.dtype)
        for i in range(w)
    )
    return out + params["conv_b"].astype(u.dtype), padded[:, -(w - 1) :, :]


def rglru_forward(params, x: jnp.ndarray, cfg: LMConfig, conv_state=None, h_state=None):
    """x [B,S,D] -> (y [B,S,D], (conv_state, h_state))."""
    u = x @ params["w_x"].astype(x.dtype)
    u, conv_state = _conv(params, u, conv_state)
    a, bterm = _gates(params, u, cfg)  # [B,S,rw] fp32
    if h_state is not None:
        # fold the carried state into the first step's additive term
        bterm = bterm.at[:, 0, :].add(a[:, 0, :] * h_state.astype(jnp.float32))

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (a, bterm), axis=1)
    h_state = h[:, -1, :]
    gate = jax.nn.gelu(x @ params["w_y"].astype(x.dtype))
    y = (h.astype(x.dtype) * gate) @ params["w_out"].astype(x.dtype)
    return y, (conv_state, h_state)


def rglru_decode(params, x: jnp.ndarray, cfg: LMConfig, conv_state, h_state):
    """x [B,1,D] single step."""
    u = x @ params["w_x"].astype(x.dtype)
    u, conv_state = _conv(params, u, conv_state)
    a, bterm = _gates(params, u, cfg)
    h = a[:, 0] * h_state.astype(jnp.float32) + bterm[:, 0]
    gate = jax.nn.gelu(x @ params["w_y"].astype(x.dtype))
    y = (h[:, None, :].astype(x.dtype) * gate) @ params["w_out"].astype(x.dtype)
    return y, (conv_state, h)


def init_rglru_cache(cfg: LMConfig, batch: int, dtype):
    rw = cfg.rnn_width or cfg.d_model
    conv = jnp.zeros((batch, cfg.ssm_conv_width - 1, rw), dtype)
    h = jnp.zeros((batch, rw), jnp.float32)
    return conv, h
