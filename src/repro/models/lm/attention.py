"""GQA attention: RoPE/M-RoPE, qk-norm, bias, windowing, KV cache.

Two implementations with identical semantics:
  * "xla"   — einsum attention (used for dry-run/roofline compiles; XLA's
              TPU fusions handle it and cost analysis stays transparent)
  * "flash" — the Pallas online-softmax kernel (kernels/flash_attention),
              the TPU-target artifact; interpret=True on CPU tests.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ...dist.sharding import shard
from .config import LMConfig
from .layers import P, apply_rope, rms_norm

NEG_INF = -1e30


def attention_specs(cfg: LMConfig, *, layers: int | None = None, cross: bool = False) -> dict:
    d = cfg.d_model
    hq = cfg.num_heads * cfg.head_dim
    hkv = cfg.num_kv_heads * cfg.head_dim
    lead = () if layers is None else (layers,)
    lax_ = () if layers is None else ("layers",)
    specs = {
        "wq": P(lead + (d, hq), lax_ + ("embed", "heads")),
        "wk": P(lead + (d, hkv), lax_ + ("embed", "kv_heads")),
        "wv": P(lead + (d, hkv), lax_ + ("embed", "kv_heads")),
        "wo": P(lead + (hq, d), lax_ + ("heads", "embed")),
    }
    if cfg.qkv_bias and not cross:
        specs.update(
            bq=P(lead + (hq,), lax_ + ("heads",), init="zeros"),
            bk=P(lead + (hkv,), lax_ + ("kv_heads",), init="zeros"),
            bv=P(lead + (hkv,), lax_ + ("kv_heads",), init="zeros"),
        )
    if cfg.qk_norm and not cross:
        specs.update(
            q_norm=P(lead + (cfg.head_dim,), lax_ + (None,), init="ones"),
            k_norm=P(lead + (cfg.head_dim,), lax_ + (None,), init="ones"),
        )
    return specs


@dataclasses.dataclass
class AttnCache:
    """KV cache: full-context or ring-buffered (local attention)."""

    k: jnp.ndarray    # [B, S_cache, Hkv, Dh]
    v: jnp.ndarray    # [B, S_cache, Hkv, Dh]
    pos: jnp.ndarray  # [B, S_cache] absolute position of each slot (-1 empty)


jax.tree_util.register_pytree_node(
    AttnCache,
    lambda c: ((c.k, c.v, c.pos), None),
    lambda _, ch: AttnCache(*ch),
)


def init_attn_cache(cfg: LMConfig, batch: int, cache_len: int, dtype) -> AttnCache:
    eff = min(cache_len, cfg.window) if cfg.window else cache_len
    return AttnCache(
        k=jnp.zeros((batch, eff, cfg.num_kv_heads, cfg.head_dim), dtype),
        v=jnp.zeros((batch, eff, cfg.num_kv_heads, cfg.head_dim), dtype),
        pos=jnp.full((batch, eff), -1, jnp.int32),
    )


def _project_qkv(params, x, cfg: LMConfig, *, qseq: bool = False):
    b, s, _ = x.shape
    q = x @ params["wq"].astype(x.dtype)
    k = x @ params["wk"].astype(x.dtype)
    v = x @ params["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    if qseq:
        # full-sequence path: project directly into the context-parallel
        # layout the attention uses — resharding q/k/v from heads-sharded
        # to qseq-sharded costs an all-gather + copy per layer (§Perf HC1).
        # k/v replicate over `model` (GQA keys are small; the wk/wv weight
        # gather is cheaper than resharding activations).
        q = shard(q, "act_batch", "act_qseq", None)
        k = shard(k, "act_batch", None, None)
        v = shard(v, "act_batch", None, None)
    else:
        q = shard(q, "act_batch", "act_seq", "act_heads")
        k = shard(k, "act_batch", "act_seq", "act_heads")
        v = shard(v, "act_batch", "act_seq", "act_heads")
    q = q.reshape(b, s, cfg.num_heads, cfg.head_dim)
    k = k.reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    return q, k, v


def _sdpa_xla(q, k, v, mask, cfg: LMConfig, *, shard_qseq: bool = False):
    """q [B,Sq,Hq,Dh], k/v [B,Sk,Hkv,Dh], mask [B,Sq,Sk] bool.

    ``shard_qseq`` enables context-parallel attention: scores shard over
    the q-sequence dim on `model` (head counts rarely divide a 16-way TP
    axis; q-seq always does for the assigned shapes).  k/v replicate over
    `model` — a small all-gather instead of an S×S score all-reduce."""
    b, sq, hq, dh = q.shape
    hkv = k.shape[2]
    group = hq // hkv
    if shard_qseq:
        q = shard(q, "act_batch", "act_qseq", None, None)
        k = shard(k, "act_batch", None, None, None)
        v = shard(v, "act_batch", None, None, None)
    qg = q.reshape(b, sq, hkv, group, dh)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) * (dh ** -0.5)
    if cfg.logits_soft_cap:
        logits = cfg.logits_soft_cap * jnp.tanh(logits / cfg.logits_soft_cap)
    if shard_qseq:
        logits = shard(logits, "act_batch", None, None, "act_qseq", None)
    logits = jnp.where(mask[:, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
    if shard_qseq:
        out = shard(out, "act_batch", "act_qseq", None, None, None)
    return out.reshape(b, sq, hq, dh)


def _sdpa_flash_xla(
    q, k, v, cfg: LMConfig, *, causal: bool, window: int | None,
    q_chunk: int = 1024, k_chunk: int = 2048,
):
    """Chunked online-softmax attention in pure XLA — the compile/roofline
    stand-in for the Pallas flash kernel: no S×S score tensor ever exists.
    q chunks are vectorized (and context-parallel over `model`); k chunks
    stream through a scan carrying (m, l, acc) — the paper's softmax
    decomposition (Fig. 6) at the XLA level."""
    b, s, hq, dh = q.shape
    hkv, sk = k.shape[2], k.shape[1]
    group = hq // hkv
    qc = min(q_chunk, s)
    kc = min(k_chunk, sk)
    nq, nk = s // qc, sk // kc
    scale = dh ** -0.5
    qr = q.reshape(b, nq, qc, hkv, group, dh)
    qr = shard(qr, "act_batch", "act_qseq", None, None, None, None)
    kr = k.reshape(b, nk, kc, hkv, dh)
    vr = v.reshape(b, nk, kc, hkv, dh)
    qpos = (jnp.arange(nq)[:, None] * qc + jnp.arange(qc)[None, :]) + (sk - s)

    def _cshard(c):
        m_, l_, a_ = c
        return (
            shard(m_, "act_batch", "act_qseq", None, None, None),
            shard(l_, "act_batch", "act_qseq", None, None, None),
            shard(a_, "act_batch", "act_qseq", None, None, None, None),
        )

    def kstep(carry, inp):
        m_run, l_run, acc = _cshard(carry)  # [b,nq,hkv,g,qc], same, [...,dh]
        kb, vb, koff = inp                  # [b,kc,hkv,dh], [b,kc,hkv,dh], scalar
        sblk = jnp.einsum("bnqhgd,bkhd->bnhgqk", qr, kb).astype(jnp.float32) * scale
        sblk = shard(sblk, "act_batch", "act_qseq", None, None, None, None)
        if cfg.logits_soft_cap:
            sblk = cfg.logits_soft_cap * jnp.tanh(sblk / cfg.logits_soft_cap)
        kpos = koff + jnp.arange(kc)
        mask = jnp.ones((nq, qc, kc), bool)
        if causal:
            mask &= kpos[None, None, :] <= qpos[:, :, None]
        if window is not None:
            mask &= kpos[None, None, :] > qpos[:, :, None] - window
        mask6 = mask[None, :, None, None, :, :]  # [1,nq,1,1,qc,kc]
        sblk = jnp.where(mask6, sblk, NEG_INF)
        m_new = jnp.maximum(m_run, sblk.max(axis=-1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(sblk - m_new[..., None])
        p = jnp.where(mask6, p, 0.0)
        l_new = l_run * alpha + p.sum(-1)
        upd = jnp.einsum("bnhgqk,bkhd->bnhgqd", p.astype(q.dtype), vb).astype(jnp.float32)
        acc_new = acc * alpha[..., None] + upd
        return _cshard((m_new, l_new, acc_new)), None

    init = _cshard((
        jnp.full((b, nq, hkv, group, qc), NEG_INF, jnp.float32),
        jnp.zeros((b, nq, hkv, group, qc), jnp.float32),
        jnp.zeros((b, nq, hkv, group, qc, dh), jnp.float32),
    ))
    xs = (
        kr.transpose(1, 0, 2, 3, 4),
        vr.transpose(1, 0, 2, 3, 4),
        jnp.arange(nk) * kc,
    )
    (m_f, l_f, acc), _ = jax.lax.scan(kstep, init, xs)
    out = acc / jnp.maximum(l_f, 1e-9)[..., None]
    out = out.astype(q.dtype).transpose(0, 1, 4, 2, 3, 5)  # b,nq,qc,hkv,g,dh
    return out.reshape(b, s, hq, dh)


def attention_forward(
    params: dict,
    x: jnp.ndarray,           # [B, S, D]
    cfg: LMConfig,
    *,
    angles: jnp.ndarray | None,   # [B, S, Dh//2] rope angles (None: no rope)
    window: int | None = None,
    causal: bool = True,
    impl: str = "xla",
) -> jnp.ndarray:
    """Full-sequence (train / prefill) self-attention."""
    b, s, _ = x.shape
    # NOTE §Perf HC1-iter1 (refuted): qseq=True here *increases* collective
    # volume — projecting into the context-parallel layout conflicts with
    # the model-axis weight sharding and XLA gathers activations instead.
    q, k, v = _project_qkv(params, x, cfg, qseq=False)
    if angles is not None:
        q = apply_rope(q, angles)
        k = apply_rope(k, angles)
    if impl == "flash" or impl == "flash_interpret":
        from ...kernels import flash_attention

        out = flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
            causal=causal, window=window,
            interpret=impl == "flash_interpret",
            block_q=min(512, s), block_k=min(512, s),
        ).transpose(0, 2, 1, 3)
    elif s >= 8192:  # long-context: never materialize S×S scores
        out = _sdpa_flash_xla(q, k, v, cfg, causal=causal, window=window)
    else:
        qpos = jnp.arange(s)[:, None]
        kpos = jnp.arange(s)[None, :]
        mask = jnp.ones((s, s), bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        out = _sdpa_xla(q, k, v, jnp.broadcast_to(mask, (b, s, s)), cfg, shard_qseq=True)
    out = out.reshape(b, s, cfg.num_heads * cfg.head_dim)
    out = shard(out, "act_batch", "act_seq", "act_heads")
    return out @ params["wo"].astype(x.dtype)


def attention_decode(
    params: dict,
    x: jnp.ndarray,            # [B, 1, D]
    cfg: LMConfig,
    cache: AttnCache,
    cache_pos: jnp.ndarray,    # int32 scalar, or [B] per-slot positions
    *,
    angles: jnp.ndarray | None,  # [B, 1, Dh//2]
    window: int | None = None,
) -> tuple[jnp.ndarray, AttnCache]:
    """Single-token decode against a (possibly ring-buffered) KV cache.

    ``cache_pos`` may be a per-batch-row vector: continuous batching
    admits requests mid-stream, and each slot masks/writes at its OWN
    ring position (serve/batcher.py) rather than a shared counter."""
    b = x.shape[0]
    q, k_new, v_new = _project_qkv(params, x, cfg)
    if angles is not None:
        q = apply_rope(q, angles)
        k_new = apply_rope(k_new, angles)
    slot_len = cache.k.shape[1]
    cp = jnp.broadcast_to(jnp.asarray(cache_pos, jnp.int32), (b,))
    if window is not None:
        slot = cp % slot_len  # ring buffer
    else:
        slot = jnp.minimum(cp, slot_len - 1)
    rows = jnp.arange(b)
    k = cache.k.at[rows, slot].set(k_new[:, 0].astype(cache.k.dtype))
    v = cache.v.at[rows, slot].set(v_new[:, 0].astype(cache.v.dtype))
    pos = cache.pos.at[rows, slot].set(cp)
    valid = (pos >= 0) & (pos <= cp[:, None])
    if window is not None:
        valid &= pos > (cp - window)[:, None]
    out = _sdpa_xla(q, k, v, valid[:, None, :], cfg)  # [B,1,Hq,Dh]
    out = out.reshape(b, 1, cfg.num_heads * cfg.head_dim)
    return out @ params["wo"].astype(x.dtype), AttnCache(k=k, v=v, pos=pos)


def cross_attention_forward(
    params: dict,
    x: jnp.ndarray,        # [B, Sq, D]
    kv: tuple[jnp.ndarray, jnp.ndarray],  # precomputed enc K/V [B, Sk, Hkv, Dh]
    cfg: LMConfig,
) -> jnp.ndarray:
    b, sq, _ = x.shape
    q = (x @ params["wq"].astype(x.dtype)).reshape(b, sq, cfg.num_heads, cfg.head_dim)
    k, v = kv
    mask = jnp.ones((b, sq, k.shape[1]), bool)
    out = _sdpa_xla(q, k, v, mask, cfg, shard_qseq=True).reshape(b, sq, -1)
    return out @ params["wo"].astype(x.dtype)


def encode_cross_kv(params: dict, enc_out: jnp.ndarray, cfg: LMConfig):
    b, sk, _ = enc_out.shape
    k = (enc_out @ params["wk"].astype(enc_out.dtype)).reshape(b, sk, cfg.num_kv_heads, cfg.head_dim)
    v = (enc_out @ params["wv"].astype(enc_out.dtype)).reshape(b, sk, cfg.num_kv_heads, cfg.head_dim)
    return k, v
