"""HiHGNN core: bound-aware stage fusion, independency-aware parallel
execution (lane scheduling), similarity-aware execution scheduling, and
RAB-style data-reuse accounting."""
from . import stages
from .fusion import (
    FusedFPInputs,
    NABackend,
    SemanticGraphBatch,
    batch_semantic_graph,
    build_unit_tables,
    cpu_fallback,
    mean_aggregate,
    neighbor_aggregate,
    neighbor_aggregate_multi,
)
from .reuse import FPTraffic, ReuseCounters, count_reuse, fp_buffer_traffic
from .scheduling import (
    LanePlan,
    brute_force_hamilton_path,
    lane_assignment,
    naive_lane_assignment,
    shortest_hamilton_path,
    similarity_matrix,
    similarity_schedule,
)

__all__ = [
    "stages",
    "FusedFPInputs",
    "NABackend",
    "SemanticGraphBatch",
    "batch_semantic_graph",
    "build_unit_tables",
    "cpu_fallback",
    "mean_aggregate",
    "neighbor_aggregate",
    "neighbor_aggregate_multi",
    "FPTraffic",
    "ReuseCounters",
    "count_reuse",
    "fp_buffer_traffic",
    "LanePlan",
    "brute_force_hamilton_path",
    "lane_assignment",
    "naive_lane_assignment",
    "shortest_hamilton_path",
    "similarity_matrix",
    "similarity_schedule",
]
