"""HGNN execution stages (reference semantics, pure jnp).

The paper decomposes HGNN execution into FP -> (theta) -> NA -> LSF -> GSF
(Algorithm 2).  This module is the functional ground truth for each
fine-grained stage; fusion.py composes them into fused/staged execution
paths and kernels/ provides the TPU Pallas implementations.

Conventions:
  * multi-head features are [N, H, Dh]; attention coefficients are [N, H]
  * edge lists are dst-sorted PaddedEdges (src, dst, valid)
  * all ops are jit/vmap/shard_map friendly (static shapes, no host sync)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def feature_projection(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray | None = None) -> jnp.ndarray:
    """FP stage: h' = x @ W (+ b).  x: [N, Din], w: [Din, H*Dh] -> [N, H*Dh].

    Type-specific projection is expressed by calling this once per vertex
    type — the functional RAB: each vertex is projected exactly once and
    the result is *gathered* everywhere it is needed (DESIGN.md §2).
    """
    h = x @ w
    if b is not None:
        h = h + b
    return h


def attention_coefficients(
    h: jnp.ndarray, a_src: jnp.ndarray, a_dst: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """The fused first-half of NA (paper Alg. 2 line 8): per-vertex GAT
    coefficients theta_src[u] = <h'_u, a_src>, theta_dst[v] = <h'_v, a_dst>.

    h: [N, H, Dh]; a_*: [H, Dh] -> ([N, H], [N, H]).  Computed once per
    (vertex, semantic graph) and reused for every incident edge — the
    second reuse the RAB tracks.
    """
    th_s = jnp.einsum("nhd,hd->nh", h, a_src)
    th_d = jnp.einsum("nhd,hd->nh", h, a_dst)
    return th_s, th_d


def segment_softmax_aggregate(
    src: jnp.ndarray,
    dst: jnp.ndarray,
    valid: jnp.ndarray,
    theta_src: jnp.ndarray,
    theta_dst: jnp.ndarray,
    h_src: jnp.ndarray,
    num_dst: int,
    *,
    leaky_slope: float = 0.2,
    edge_bias: jnp.ndarray | float = 0.0,
) -> jnp.ndarray:
    """NA stage reference: two-pass segment softmax attention aggregation.

    z_v = sum_u softmax_u(LeakyReLU(theta_dst[v] + theta_src[u] + bias)) h'_u

    Shapes: src/dst/valid [E]; theta_* [N, H]; h_src [Ns, H, Dh] -> [Nd, H, Dh].
    """
    logits = jax.nn.leaky_relu(theta_dst[dst] + theta_src[src] + edge_bias, leaky_slope)
    logits = jnp.where(valid[:, None], logits, NEG_INF)
    m = jax.ops.segment_max(logits, dst, num_segments=num_dst)  # [Nd, H]
    m = jnp.maximum(m, NEG_INF)  # isolated vertices: keep finite
    p = jnp.exp(logits - m[dst])
    p = jnp.where(valid[:, None], p, 0.0)
    denom = jax.ops.segment_sum(p, dst, num_segments=num_dst)  # [Nd, H]
    num = jax.ops.segment_sum(p[:, :, None] * h_src[src], dst, num_segments=num_dst)
    return num / jnp.maximum(denom, 1e-9)[:, :, None]


def segment_mean_aggregate(
    src: jnp.ndarray,
    dst: jnp.ndarray,
    valid: jnp.ndarray,
    h_src: jnp.ndarray,
    num_dst: int,
) -> jnp.ndarray:
    """R-GCN NA: z_v = (1/|N_v|) sum_{u in N_v} h'_u.  h_src [Ns, ...]."""
    w = valid.astype(h_src.dtype)
    deg = jax.ops.segment_sum(w, dst, num_segments=num_dst)
    shaped = w.reshape((-1,) + (1,) * (h_src.ndim - 1))
    num = jax.ops.segment_sum(h_src[src] * shaped, dst, num_segments=num_dst)
    return num / jnp.maximum(deg, 1.0).reshape((-1,) + (1,) * (h_src.ndim - 1))


def block_softmax_aggregate(
    col_index: jnp.ndarray,   # int32 [R, W]   (-1 = padding)
    masks: jnp.ndarray,       # bool  [R, W, B, B]
    theta_src: jnp.ndarray,   # [Ns_pad, H]
    theta_dst: jnp.ndarray,   # [Nd_pad, H]
    h_src: jnp.ndarray,       # [Ns_pad, H, Dh]
    *,
    leaky_slope: float = 0.2,
    edge_bias: jnp.ndarray | float = 0.0,
) -> jnp.ndarray:
    """Block-CSR *online-softmax* NA — the paper's softmax decomposition
    (numerator and denominator accumulated simultaneously, Fig. 6), in the
    block-densified TPU layout.  Pure-jnp oracle for kernels/seg_gat_agg.

    Returns [Nd_pad, H, Dh].
    """
    R, W = col_index.shape
    B = masks.shape[-1]
    H, Dh = theta_src.shape[1], h_src.shape[-1]
    th_d = theta_dst.reshape(R, B, H)

    def row(carry_r, row_inputs):
        cols, mrow = row_inputs  # [W], [W, B, B]

        def step(carry, inp):
            m_run, l_run, acc = carry  # [B,H], [B,H], [B,H,Dh]
            c, mask = inp  # scalar, [B, B]
            c_safe = jnp.maximum(c, 0)
            th_s = jax.lax.dynamic_slice_in_dim(theta_src, c_safe * B, B, 0)  # [B,H]
            hs = jax.lax.dynamic_slice_in_dim(h_src, c_safe * B, B, 0)  # [B,H,Dh]
            logits = jax.nn.leaky_relu(
                carry_r[:, None, :] + th_s[None, :, :] + edge_bias, leaky_slope
            )  # [B(dst), B(src), H]
            live = mask[:, :, None] & (c >= 0)
            logits = jnp.where(live, logits, NEG_INF)
            m_blk = jnp.max(logits, axis=1)  # [B, H]
            m_new = jnp.maximum(m_run, m_blk)
            scale = jnp.exp(m_run - m_new)
            p = jnp.exp(logits - m_new[:, None, :])  # [B, B, H]
            p = jnp.where(live, p, 0.0)
            l_new = l_run * scale + p.sum(axis=1)
            acc_new = acc * scale[:, :, None] + jnp.einsum("dsh,shf->dhf", p, hs)
            return (m_new, l_new, acc_new), None

        # f32 carries regardless of input dtype — matches the Pallas
        # kernels' f32 accumulation; only the final output is cast back.
        init = (
            jnp.full((B, H), NEG_INF, jnp.float32),
            jnp.zeros((B, H), jnp.float32),
            jnp.zeros((B, H, Dh), jnp.float32),
        )
        (m_f, l_f, acc_f), _ = jax.lax.scan(step, init, (cols, mrow))
        return acc_f / jnp.maximum(l_f, 1e-9)[:, :, None]

    out = jax.vmap(row)(th_d, (col_index, masks))  # [R, B, H, Dh]
    return out.reshape(R * B, H, Dh).astype(h_src.dtype)


def local_semantic_fusion(
    z: jnp.ndarray, w_g: jnp.ndarray, b_g: jnp.ndarray, q: jnp.ndarray, valid_dst: jnp.ndarray
) -> jnp.ndarray:
    """LSF stage (paper Alg. 2 line 21): per-semantic-graph partial semantic
    importance w_P = (1/|V|) sum_v q^T tanh(W_g z_v + b).  Fusable into NA
    completion — it only needs each vertex's finished aggregate once.

    z: [Nd, D]; w_g: [D, Da]; q: [Da]; valid_dst: [Nd] -> scalar.
    """
    s = jnp.tanh(z @ w_g + b_g) @ q  # [Nd]
    s = jnp.where(valid_dst, s, 0.0)
    return s.sum() / jnp.maximum(valid_dst.sum(), 1.0)


def global_semantic_fusion(
    w_p: jnp.ndarray, z_stack: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """GSF stage: beta = softmax_P(w_P); h_v = sum_P beta_P z_v^P.

    w_p: [P]; z_stack: [P, Nd, D] -> ([Nd, D], beta [P]).
    """
    beta = jax.nn.softmax(w_p)
    return jnp.einsum("p,pnd->nd", beta, z_stack), beta
