"""Bound-aware stage fusion — execution paths for the NA stage (paper §4.1).

Three interchangeable NA backends with identical semantics:

* ``SEGMENT``  — two-pass segment softmax over a padded edge list.  This is
  the *staged baseline*: it mirrors the GPU framework's SpMM-style pass
  structure (materialize per-edge logits, reduce max, exponentiate, reduce
  sum, weighted SpMM).
* ``BLOCK``    — pure-jnp block-CSR online softmax (numerator/denominator
  accumulated simultaneously — the paper's softmax decomposition, Fig. 6).
* ``KERNEL``   — the Pallas TPU kernel (kernels/seg_gat_agg): the fused
  FP->theta->NA->LSF hardware datapath expressed as VMEM-tiled MXU work.
  ``KERNEL_INTERPRET`` runs the same kernel body in interpret mode (CPU).

Stage fusion proper — running FP, theta, NA, LSF inside *one* compiled
program instead of one program per stage — is expressed at the model level
(models/hgnn): `fused=True` jits the whole layer, `fused=False` runs each
stage as its own jitted program with host barriers between them, mirroring
Fig. 4(a) vs 4(b).
"""
from __future__ import annotations

import dataclasses
import enum

import jax
import jax.numpy as jnp
import numpy as np

from ..graphs.formats import to_block_csr, to_padded_edges
from ..graphs.hetgraph import SemanticGraph
from ..obs.trace import trace_span
from . import stages


class NABackend(enum.Enum):
    SEGMENT = "segment"
    BLOCK = "block"
    KERNEL = "kernel"
    KERNEL_INTERPRET = "kernel_interpret"
    # fused multigraph kernel (kernels/seg_gat_agg_multigraph): ALL semantic
    # graphs of a layer in one Pallas launch — the paper's multi-lane
    # datapath.  Differentiable (custom VJP with a fused backward launch).
    MULTIGRAPH = "multigraph"
    MULTIGRAPH_INTERPRET = "multigraph_interpret"
    # stage-fusion megakernel (kernels/seg_gat_agg_fused_fp): the
    # multigraph launch with the FP stage pulled INSIDE — raw features
    # stream from HBM and are projected on-chip against per-graph weight
    # tables; h' never materializes (paper Alg. 2, DESIGN.md §10).
    # Requires fp=FusedFPInputs instead of theta/h operands.
    FUSED_FP = "fused_fp"
    FUSED_FP_INTERPRET = "fused_fp_interpret"


_MULTIGRAPH_BACKENDS = (NABackend.MULTIGRAPH, NABackend.MULTIGRAPH_INTERPRET)
_FUSED_FP_BACKENDS = (NABackend.FUSED_FP, NABackend.FUSED_FP_INTERPRET)
# materialized-path equivalent of each fused backend (e.g. for serving's
# FP-cache-hit bypass: the projected table already exists, so re-projecting
# inside the kernel would waste the cache)
_FUSED_TO_MULTIGRAPH = {
    NABackend.FUSED_FP: NABackend.MULTIGRAPH,
    NABackend.FUSED_FP_INTERPRET: NABackend.MULTIGRAPH_INTERPRET,
}

# Compiled Pallas backends need a TPU; each maps to the interpreter variant
# of the SAME kernel body (same numbers) for CPU-only hosts.
_CPU_FALLBACK = {
    NABackend.KERNEL: NABackend.KERNEL_INTERPRET,
    NABackend.MULTIGRAPH: NABackend.MULTIGRAPH_INTERPRET,
    NABackend.FUSED_FP: NABackend.FUSED_FP_INTERPRET,
}


def cpu_fallback(backend: NABackend) -> NABackend:
    """Degrade a compiled Pallas backend to its interpret twin on CPU hosts.

    The launchers (serve, train) and tests all need the same policy: ask
    for the TPU kernel, validate the identical kernel body under the
    interpreter when no TPU is attached.  No-op for non-kernel backends
    and on TPU hosts.
    """
    if backend in _CPU_FALLBACK and jax.default_backend() == "cpu":
        return _CPU_FALLBACK[backend]
    return backend


@dataclasses.dataclass
class SemanticGraphBatch:
    """Device-resident formats for one semantic graph."""

    name: str
    src_type: str
    dst_type: str
    num_src: int
    num_dst: int
    num_edges: int
    path_types: tuple[str, ...]
    # padded edge list (SEGMENT backend)
    src: jnp.ndarray | None = None
    dst: jnp.ndarray | None = None
    valid: jnp.ndarray | None = None
    # block CSR (BLOCK / KERNEL backends)
    col_index: jnp.ndarray | None = None
    masks: jnp.ndarray | None = None
    block: int = 128

    @property
    def num_dst_pad(self) -> int:
        if self.col_index is None:
            return self.num_dst
        return int(self.col_index.shape[0]) * self.block

    def row_edge_counts(self) -> np.ndarray:
        """#edges per dst-block row (workload units for lane scheduling)."""
        assert self.masks is not None
        return np.asarray(self.masks.sum(axis=(1, 2, 3)), np.int64)


_SGB_ARRAY_FIELDS = ("src", "dst", "valid", "col_index", "masks")
_SGB_META_FIELDS = (
    "name", "src_type", "dst_type", "num_src", "num_dst", "num_edges", "path_types", "block",
)


def _sgb_flatten(b: "SemanticGraphBatch"):
    children = tuple(getattr(b, f) for f in _SGB_ARRAY_FIELDS)
    aux = tuple(getattr(b, f) for f in _SGB_META_FIELDS)
    return children, aux


def _sgb_unflatten(aux, children):
    kw = dict(zip(_SGB_META_FIELDS, aux))
    kw.update(dict(zip(_SGB_ARRAY_FIELDS, children)))
    return SemanticGraphBatch(**kw)


jax.tree_util.register_pytree_node(SemanticGraphBatch, _sgb_flatten, _sgb_unflatten)


def batch_semantic_graph(
    sg: SemanticGraph,
    *,
    block: int = 128,
    with_edges: bool = True,
    with_blocks: bool = True,
    edge_pad: int | None = None,
) -> SemanticGraphBatch:
    kw: dict = {}
    if with_edges:
        pe = to_padded_edges(sg, pad_to=edge_pad)
        kw.update(
            src=jnp.asarray(pe.src), dst=jnp.asarray(pe.dst), valid=jnp.asarray(pe.valid)
        )
    if with_blocks:
        bc = to_block_csr(sg, block=block)
        kw.update(col_index=jnp.asarray(bc.col_index), masks=jnp.asarray(bc.masks), block=block)
    return SemanticGraphBatch(
        name=sg.name,
        src_type=sg.src_type,
        dst_type=sg.dst_type,
        num_src=sg.num_src,
        num_dst=sg.num_dst,
        num_edges=sg.num_edges,
        path_types=sg.path_types,
        **kw,
    )


@dataclasses.dataclass
class FusedFPInputs:
    """Operands of the FUSED_FP backends: raw features plus the projection
    and attention parameters the megakernel applies on-chip (in place of
    the materialized theta_src/theta_dst/h_src of the other backends).

    ``w``/``b`` are stacked per weight *table* and ``wsel`` maps each
    semantic graph to its table — graphs sharing a projection (HAN: all of
    them) share one table instead of carrying G copies through HBM.
    """

    x: jnp.ndarray       # [N, Din]      raw features (shared src/dst space)
    w: jnp.ndarray       # [T, Din, H*Dh] per-table projection weights
    b: jnp.ndarray       # [T, H*Dh]
    a_src: jnp.ndarray   # [G, H, Dh]
    a_dst: jnp.ndarray   # [G, H, Dh]
    wsel: jnp.ndarray    # int32 [G]     graph -> weight-table row

    @classmethod
    def shared(cls, x, w, b, a_src, a_dst) -> "FusedFPInputs":
        """All graphs project through ONE weight table (HAN's layout)."""
        g_n = a_src.shape[0]
        return cls(
            x=x,
            w=w[None] if w.ndim == 2 else w,
            b=b[None] if b.ndim == 1 else b,
            a_src=a_src,
            a_dst=a_dst,
            wsel=jnp.zeros((g_n,), jnp.int32),
        )


_FP_FIELDS = ("x", "w", "b", "a_src", "a_dst", "wsel")
jax.tree_util.register_pytree_node(
    FusedFPInputs,
    lambda fp: (tuple(getattr(fp, f) for f in _FP_FIELDS), None),
    lambda _, ch: FusedFPInputs(**dict(zip(_FP_FIELDS, ch))),
)


def _pad_rows(x: jnp.ndarray, n: int) -> jnp.ndarray:
    if x.shape[0] == n:
        return x
    assert x.shape[0] < n
    pad = [(0, n - x.shape[0])] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad)


def neighbor_aggregate(
    batch: SemanticGraphBatch,
    theta_src: jnp.ndarray,  # [Ns, H]
    theta_dst: jnp.ndarray,  # [Nd, H]
    h_src: jnp.ndarray,      # [Ns, H, Dh]
    *,
    backend: NABackend = NABackend.SEGMENT,
    leaky_slope: float = 0.2,
    edge_bias: jnp.ndarray | float = 0.0,
) -> jnp.ndarray:
    """Attention NA with the chosen backend.  Returns [num_dst, H, Dh]."""
    if backend in _MULTIGRAPH_BACKENDS:
        bias = edge_bias
        if not (hasattr(bias, "ndim") and bias.ndim == 2):
            bias = jnp.broadcast_to(jnp.asarray(bias, jnp.float32), (1, theta_src.shape[-1]))
        return neighbor_aggregate_multi(
            [batch], theta_src[None], theta_dst[None], h_src,
            backend=backend, leaky_slope=leaky_slope, edge_bias=bias,
        )[0]
    if backend is NABackend.SEGMENT:
        assert batch.src is not None, "batch built without edge list"
        return stages.segment_softmax_aggregate(
            batch.src, batch.dst, batch.valid, theta_src, theta_dst, h_src,
            batch.num_dst, leaky_slope=leaky_slope, edge_bias=edge_bias,
        )

    assert batch.col_index is not None, "batch built without block CSR"
    ns_pad = ((batch.num_src + batch.block - 1) // batch.block) * batch.block
    th_s = _pad_rows(theta_src, ns_pad)
    hs = _pad_rows(h_src, ns_pad)
    th_d = _pad_rows(theta_dst, batch.num_dst_pad)

    if backend is NABackend.BLOCK:
        out = stages.block_softmax_aggregate(
            batch.col_index, batch.masks, th_s, th_d, hs,
            leaky_slope=leaky_slope, edge_bias=edge_bias,
        )
    else:
        from ..kernels import ops as kops

        out = kops.seg_gat_agg(
            batch.col_index, batch.masks, th_s, th_d, hs,
            leaky_slope=leaky_slope, edge_bias=edge_bias,
            interpret=backend is NABackend.KERNEL_INTERPRET,
        )
    return out[: batch.num_dst]


def build_unit_tables(batches: list[SemanticGraphBatch]):
    """Stack the block-CSR rows of several semantic graphs into the flat
    (col_index, graph_id, dst_row, masks) work-unit layout of
    kernels/seg_gat_agg_multigraph: one unit per (graph, dst-block row),
    col widths padded to the max across graphs.

    Requires all graphs to share the dst vertex space and block size
    (HAN's metapath graphs do).  Host-side; build once per layer.
    """
    assert batches, "no semantic graphs"
    b = batches[0].block
    n_rows = int(batches[0].col_index.shape[0])
    for bb in batches:
        assert bb.col_index is not None, "batch built without block CSR"
        assert bb.block == b and int(bb.col_index.shape[0]) == n_rows

    w_max = max(int(bb.col_index.shape[1]) for bb in batches)
    g_n = len(batches)
    col = np.full((g_n, n_rows, w_max), -1, np.int32)
    masks = np.zeros((g_n, n_rows, w_max, b, b), bool)
    for i, bb in enumerate(batches):
        wg = int(bb.col_index.shape[1])
        col[i, :, :wg] = np.asarray(bb.col_index)
        masks[i, :, :wg] = np.asarray(bb.masks)
    gid = np.repeat(np.arange(g_n, dtype=np.int32), n_rows)
    row = np.tile(np.arange(n_rows, dtype=np.int32), g_n)
    return (
        jnp.asarray(col.reshape(g_n * n_rows, w_max)),
        jnp.asarray(gid),
        jnp.asarray(row),
        jnp.asarray(masks.reshape(g_n * n_rows, w_max, b, b)),
    )


def neighbor_aggregate_multi(
    batches: list[SemanticGraphBatch],
    theta_src: jnp.ndarray | None,  # [G, Ns, H]   (None with FUSED_FP)
    theta_dst: jnp.ndarray | None,  # [G, Nd, H]   (None with FUSED_FP)
    h_src: jnp.ndarray | None,      # [Ns, H, Dh]  (None with FUSED_FP)
    *,
    backend: NABackend = NABackend.MULTIGRAPH_INTERPRET,
    leaky_slope: float = 0.2,
    edge_bias: jnp.ndarray | None = None,  # [G, H]
    unit_tables: tuple | None = None,
    fp: FusedFPInputs | None = None,
) -> jnp.ndarray:
    """NA for ALL semantic graphs of a layer at once.  Returns
    [G, num_dst, H, Dh].

    With a MULTIGRAPH backend this is a single fused Pallas launch (one
    forward and, under autodiff, one backward kernel for the whole layer);
    any other backend falls back to a per-graph loop of
    ``neighbor_aggregate`` — same semantics, G separate dispatches.
    ``unit_tables`` (from :func:`build_unit_tables`) may be passed to skip
    the host-side stacking inside jitted callers.

    With a FUSED_FP backend the FP stage runs *inside* the launch: pass
    ``fp=FusedFPInputs(...)`` (raw features + projection/attention params)
    and leave theta_src/theta_dst/h_src as None — no projected tensor is
    ever materialized in HBM (DESIGN.md §10).

    Spans (obs.trace, DESIGN.md §12): fused backends emit one ``stage=NA``
    span for the whole launch (its indivisibility is the point); the
    per-graph fallback emits one ``na/<graph>`` span per semantic graph on
    its own ``sg/<graph>`` lane row.  Under jit these fire at trace time;
    eager callers (the serving engine, obs.characterize) get real timing
    via the sync boundary.
    """
    if backend in _FUSED_FP_BACKENDS:
        if fp is None:
            raise ValueError(
                "FUSED_FP backends take fp=FusedFPInputs (raw features + "
                "weight tables) in place of theta_src/theta_dst/h_src"
            )
        from ..kernels.seg_gat_agg_fused_fp import seg_gat_agg_fused_fp

        b0 = batches[0]
        assert b0.num_src == b0.num_dst, (
            "fused FP+NA streams ONE raw-feature table for both src and dst "
            "tiles; src and dst must share the vertex space (HAN's "
            "target-type metapath graphs do)"
        )
        b = b0.block
        nd = b0.num_dst
        nd_pad = b0.num_dst_pad
        ns_pad = ((b0.num_src + b - 1) // b) * b
        if unit_tables is None:
            unit_tables = build_unit_tables(batches)
        col, gid, row, masks = unit_tables
        x_pad = _pad_rows(fp.x, max(ns_pad, nd_pad))
        g_n = len(batches)
        with trace_span(
            "na/fused_fp", stage="NA", backend=backend.value, graphs=g_n,
            units=int(col.shape[0]), fused_fp=True,
            graph_names=[bb.name for bb in batches],
        ) as sp:
            out = seg_gat_agg_fused_fp(
                col, gid, row, fp.wsel, masks, x_pad, fp.w, fp.b,
                fp.a_src, fp.a_dst, edge_bias,
                leaky_slope=leaky_slope,
                interpret=backend is NABackend.FUSED_FP_INTERPRET,
            )  # [G*R*B, H, Dh] — units are g-major, rows in order
            out = sp.sync(out)
        return out.reshape(g_n, nd_pad, *out.shape[1:])[:, :nd]

    if backend not in _MULTIGRAPH_BACKENDS:
        outs = []
        for i, bb in enumerate(batches):
            with trace_span(
                f"na/{bb.name}", stage="NA", lane=f"sg/{bb.name}",
                graph=bb.name, backend=backend.value, edges=bb.num_edges,
            ) as sp:
                z = neighbor_aggregate(
                    bb, theta_src[i], theta_dst[i], h_src[: bb.num_src],
                    backend=backend, leaky_slope=leaky_slope,
                    edge_bias=0.0 if edge_bias is None else edge_bias[i],
                )
                outs.append(sp.sync(z))
        return jnp.stack(outs)

    from ..kernels.seg_gat_agg_multigraph import seg_gat_agg_multigraph

    b = batches[0].block
    nd = batches[0].num_dst
    nd_pad = batches[0].num_dst_pad
    ns_pad = ((batches[0].num_src + b - 1) // b) * b
    if unit_tables is None:
        unit_tables = build_unit_tables(batches)
    col, gid, row, masks = unit_tables

    th_s = _pad_rows(theta_src.swapaxes(0, 1), ns_pad).swapaxes(0, 1)
    th_d = _pad_rows(theta_dst.swapaxes(0, 1), nd_pad).swapaxes(0, 1)
    hs = _pad_rows(h_src, ns_pad)
    g_n = len(batches)
    with trace_span(
        "na/multigraph", stage="NA", backend=backend.value, graphs=g_n,
        units=int(col.shape[0]), graph_names=[bb.name for bb in batches],
    ) as sp:
        out = seg_gat_agg_multigraph(
            col, gid, row, masks, th_s, th_d, hs, edge_bias,
            leaky_slope=leaky_slope,
            interpret=backend is NABackend.MULTIGRAPH_INTERPRET,
        )  # [G*R*B, H, Dh] — units are g-major, rows in order
        out = sp.sync(out)
    return out.reshape(g_n, nd_pad, *out.shape[1:])[:, :nd]


def mean_aggregate(
    batch: SemanticGraphBatch, h_src: jnp.ndarray
) -> jnp.ndarray:
    """Mean NA (R-GCN).  Returns [num_dst, ...]."""
    assert batch.src is not None
    return stages.segment_mean_aggregate(batch.src, batch.dst, batch.valid, h_src, batch.num_dst)
