"""Bound-aware stage fusion — execution paths for the NA stage (paper §4.1).

Three interchangeable NA backends with identical semantics:

* ``SEGMENT``  — two-pass segment softmax over a padded edge list.  This is
  the *staged baseline*: it mirrors the GPU framework's SpMM-style pass
  structure (materialize per-edge logits, reduce max, exponentiate, reduce
  sum, weighted SpMM).
* ``BLOCK``    — pure-jnp block-CSR online softmax (numerator/denominator
  accumulated simultaneously — the paper's softmax decomposition, Fig. 6).
* ``KERNEL``   — the Pallas TPU kernel (kernels/seg_gat_agg): the fused
  FP->theta->NA->LSF hardware datapath expressed as VMEM-tiled MXU work.
  ``KERNEL_INTERPRET`` runs the same kernel body in interpret mode (CPU).

Stage fusion proper — running FP, theta, NA, LSF inside *one* compiled
program instead of one program per stage — is expressed at the model level
(models/hgnn): `fused=True` jits the whole layer, `fused=False` runs each
stage as its own jitted program with host barriers between them, mirroring
Fig. 4(a) vs 4(b).
"""
from __future__ import annotations

import dataclasses
import enum

import jax
import jax.numpy as jnp
import numpy as np

from ..graphs.formats import to_block_csr, to_padded_edges
from ..graphs.hetgraph import SemanticGraph
from . import stages


class NABackend(enum.Enum):
    SEGMENT = "segment"
    BLOCK = "block"
    KERNEL = "kernel"
    KERNEL_INTERPRET = "kernel_interpret"


@dataclasses.dataclass
class SemanticGraphBatch:
    """Device-resident formats for one semantic graph."""

    name: str
    src_type: str
    dst_type: str
    num_src: int
    num_dst: int
    num_edges: int
    path_types: tuple[str, ...]
    # padded edge list (SEGMENT backend)
    src: jnp.ndarray | None = None
    dst: jnp.ndarray | None = None
    valid: jnp.ndarray | None = None
    # block CSR (BLOCK / KERNEL backends)
    col_index: jnp.ndarray | None = None
    masks: jnp.ndarray | None = None
    block: int = 128

    @property
    def num_dst_pad(self) -> int:
        if self.col_index is None:
            return self.num_dst
        return int(self.col_index.shape[0]) * self.block

    def row_edge_counts(self) -> np.ndarray:
        """#edges per dst-block row (workload units for lane scheduling)."""
        assert self.masks is not None
        return np.asarray(self.masks.sum(axis=(1, 2, 3)), np.int64)


_SGB_ARRAY_FIELDS = ("src", "dst", "valid", "col_index", "masks")
_SGB_META_FIELDS = (
    "name", "src_type", "dst_type", "num_src", "num_dst", "num_edges", "path_types", "block",
)


def _sgb_flatten(b: "SemanticGraphBatch"):
    children = tuple(getattr(b, f) for f in _SGB_ARRAY_FIELDS)
    aux = tuple(getattr(b, f) for f in _SGB_META_FIELDS)
    return children, aux


def _sgb_unflatten(aux, children):
    kw = dict(zip(_SGB_META_FIELDS, aux))
    kw.update(dict(zip(_SGB_ARRAY_FIELDS, children)))
    return SemanticGraphBatch(**kw)


jax.tree_util.register_pytree_node(SemanticGraphBatch, _sgb_flatten, _sgb_unflatten)


def batch_semantic_graph(
    sg: SemanticGraph,
    *,
    block: int = 128,
    with_edges: bool = True,
    with_blocks: bool = True,
    edge_pad: int | None = None,
) -> SemanticGraphBatch:
    kw: dict = {}
    if with_edges:
        pe = to_padded_edges(sg, pad_to=edge_pad)
        kw.update(
            src=jnp.asarray(pe.src), dst=jnp.asarray(pe.dst), valid=jnp.asarray(pe.valid)
        )
    if with_blocks:
        bc = to_block_csr(sg, block=block)
        kw.update(col_index=jnp.asarray(bc.col_index), masks=jnp.asarray(bc.masks), block=block)
    return SemanticGraphBatch(
        name=sg.name,
        src_type=sg.src_type,
        dst_type=sg.dst_type,
        num_src=sg.num_src,
        num_dst=sg.num_dst,
        num_edges=sg.num_edges,
        path_types=sg.path_types,
        **kw,
    )


def _pad_rows(x: jnp.ndarray, n: int) -> jnp.ndarray:
    if x.shape[0] == n:
        return x
    assert x.shape[0] < n
    pad = [(0, n - x.shape[0])] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad)


def neighbor_aggregate(
    batch: SemanticGraphBatch,
    theta_src: jnp.ndarray,  # [Ns, H]
    theta_dst: jnp.ndarray,  # [Nd, H]
    h_src: jnp.ndarray,      # [Ns, H, Dh]
    *,
    backend: NABackend = NABackend.SEGMENT,
    leaky_slope: float = 0.2,
    edge_bias: jnp.ndarray | float = 0.0,
) -> jnp.ndarray:
    """Attention NA with the chosen backend.  Returns [num_dst, H, Dh]."""
    if backend is NABackend.SEGMENT:
        assert batch.src is not None, "batch built without edge list"
        return stages.segment_softmax_aggregate(
            batch.src, batch.dst, batch.valid, theta_src, theta_dst, h_src,
            batch.num_dst, leaky_slope=leaky_slope, edge_bias=edge_bias,
        )

    assert batch.col_index is not None, "batch built without block CSR"
    ns_pad = ((batch.num_src + batch.block - 1) // batch.block) * batch.block
    th_s = _pad_rows(theta_src, ns_pad)
    hs = _pad_rows(h_src, ns_pad)
    th_d = _pad_rows(theta_dst, batch.num_dst_pad)

    if backend is NABackend.BLOCK:
        out = stages.block_softmax_aggregate(
            batch.col_index, batch.masks, th_s, th_d, hs,
            leaky_slope=leaky_slope, edge_bias=edge_bias,
        )
    else:
        from ..kernels import ops as kops

        out = kops.seg_gat_agg(
            batch.col_index, batch.masks, th_s, th_d, hs,
            leaky_slope=leaky_slope, edge_bias=edge_bias,
            interpret=backend is NABackend.KERNEL_INTERPRET,
        )
    return out[: batch.num_dst]


def mean_aggregate(
    batch: SemanticGraphBatch, h_src: jnp.ndarray
) -> jnp.ndarray:
    """Mean NA (R-GCN).  Returns [num_dst, ...]."""
    assert batch.src is not None
    return stages.segment_mean_aggregate(batch.src, batch.dst, batch.valid, h_src, batch.num_dst)
