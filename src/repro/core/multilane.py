"""Independency-aware parallel execution (paper §4.2) — multi-lane NA.

Work units are (semantic graph, dst-block row) pairs: each dst vertex
lives in exactly one unit, so units are embarrassingly parallel until the
GSF barrier, exactly the independency the paper exploits.  Units are
assigned to lanes by the workload-aware scheduler (scheduling.py); lanes
execute as a vmapped axis on one chip or as a `shard_map` mesh axis across
chips — "adding hardware resources to further improve performance"
(paper §4.2.1) becomes adding devices to the lane axis.

All units share one static shape (W block slots, padded with -1 columns),
so lane execution is a single dense program regardless of how irregular
the semantic graphs are — the TPU answer to the crossbar/scheduler
machinery of the accelerator.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec

from ..obs.trace import trace_span
from .fusion import FusedFPInputs, SemanticGraphBatch
from .scheduling import LanePlan, lane_assignment, naive_lane_assignment

NEG_INF = -1e30


@dataclasses.dataclass
class MultiLanePlan:
    """Static multi-lane execution plan (device arrays).

    Shapes: L lanes × U units/lane (padded) × W block slots × B×B masks.
    """

    col_index: jnp.ndarray  # int32 [L, U, W]
    masks: jnp.ndarray      # bool  [L, U, W, B, B]
    graph_id: jnp.ndarray   # int32 [L, U]
    dst_row: jnp.ndarray    # int32 [L, U]
    valid: jnp.ndarray      # bool  [L, U]
    block: int
    num_graphs: int
    n_dst_blocks: int       # per graph (shared dst space)
    lane_plan: LanePlan | None  # host-side scheduling metadata (not traced)

    @property
    def num_lanes(self) -> int:
        return int(self.col_index.shape[0])


def _flatten_unflatten():
    arr = ("col_index", "masks", "graph_id", "dst_row", "valid")
    # lane_plan holds host-side numpy arrays (scheduling metadata); it must
    # NOT ride in the pytree aux (aux must be hashable) — reconstructed
    # copies carry None there, which multilane_na never reads.
    meta = ("block", "num_graphs", "n_dst_blocks")

    def fl(p):
        return tuple(getattr(p, f) for f in arr), tuple(getattr(p, f) for f in meta)

    def unfl(aux, children):
        kw = dict(zip(meta, aux))
        kw.update(dict(zip(arr, children)))
        return MultiLanePlan(lane_plan=None, **kw)

    jax.tree_util.register_pytree_node(MultiLanePlan, fl, unfl)


_flatten_unflatten()


def build_multilane_plan(
    batches: list[SemanticGraphBatch],
    num_lanes: int,
    *,
    balanced: bool = True,
    threshold: float | None = None,
) -> MultiLanePlan:
    """Partition the block rows of all semantic graphs onto lanes.

    Requires all graphs to share the dst/src vertex space (HAN's metapath
    graphs do); col widths are padded to the max across graphs.
    """
    assert batches, "no semantic graphs"
    b = batches[0].block
    n_rows = int(batches[0].col_index.shape[0])
    for bb in batches:
        assert bb.block == b and int(bb.col_index.shape[0]) == n_rows

    row_costs = [bb.row_edge_counts() for bb in batches]
    plan = (
        lane_assignment(row_costs, num_lanes, threshold=threshold)
        if balanced
        else naive_lane_assignment(row_costs, num_lanes)
    )

    w_max = max(int(bb.col_index.shape[1]) for bb in batches)
    lanes_units: list[list[int]] = [[] for _ in range(num_lanes)]
    for u in range(plan.unit_graph.shape[0]):
        lanes_units[int(plan.unit_lane[u])].append(u)
    u_max = max(1, max(len(lu) for lu in lanes_units))

    col = np.full((num_lanes, u_max, w_max), -1, np.int32)
    masks = np.zeros((num_lanes, u_max, w_max, b, b), bool)
    gid = np.zeros((num_lanes, u_max), np.int32)
    drow = np.zeros((num_lanes, u_max), np.int32)
    valid = np.zeros((num_lanes, u_max), bool)
    for l, lu in enumerate(lanes_units):
        for j, u in enumerate(lu):
            g = int(plan.unit_graph[u])
            r = int(plan.unit_row[u])
            wg = int(batches[g].col_index.shape[1])
            col[l, j, :wg] = np.asarray(batches[g].col_index[r])
            masks[l, j, :wg] = np.asarray(batches[g].masks[r])
            gid[l, j] = g
            drow[l, j] = r
            valid[l, j] = True
    return MultiLanePlan(
        col_index=jnp.asarray(col),
        masks=jnp.asarray(masks),
        graph_id=jnp.asarray(gid),
        dst_row=jnp.asarray(drow),
        valid=jnp.asarray(valid),
        block=b,
        num_graphs=len(batches),
        n_dst_blocks=n_rows,
        lane_plan=plan,
    )


def _unit_na(
    cols: jnp.ndarray,   # [W]
    mrow: jnp.ndarray,   # [W, B, B]
    gid: jnp.ndarray,    # scalar
    drow: jnp.ndarray,   # scalar
    theta_src: jnp.ndarray,  # [G, Ns_pad, H]
    theta_dst: jnp.ndarray,  # [G, Nd_pad, H]
    h_src: jnp.ndarray,      # [Ns_pad, H, Dh]
    edge_bias: jnp.ndarray,  # [G, H]
    leaky_slope: float,
) -> jnp.ndarray:
    b = mrow.shape[-1]
    h_dim, dh = theta_src.shape[-1], h_src.shape[-1]
    th_d = jax.lax.dynamic_slice(
        theta_dst, (gid, drow * b, 0), (1, b, h_dim)
    )[0]  # [B, H]
    bias = edge_bias[gid]  # [H]

    def step(carry, inp):
        m_run, l_run, acc = carry
        c, mask = inp
        c_safe = jnp.maximum(c, 0)
        th_s = jax.lax.dynamic_slice(theta_src, (gid, c_safe * b, 0), (1, b, h_dim))[0]
        hs = jax.lax.dynamic_slice_in_dim(h_src, c_safe * b, b, 0)
        logits = jax.nn.leaky_relu(
            th_d[:, None, :] + th_s[None, :, :] + bias, leaky_slope
        )
        live = mask[:, :, None] & (c >= 0)
        logits = jnp.where(live, logits, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(logits, axis=1))
        scale = jnp.exp(m_run - m_new)
        p = jnp.where(live, jnp.exp(logits - m_new[:, None, :]), 0.0)
        l_new = l_run * scale + p.sum(axis=1)
        acc_new = acc * scale[:, :, None] + jnp.einsum("dsh,shf->dhf", p, hs)
        return (m_new, l_new, acc_new), None

    # f32 carries regardless of input dtype — bf16 online-softmax state
    # drifts badly over long W sweeps; the Pallas kernels accumulate in
    # f32 too, so this keeps the reference and kernel paths comparable.
    init = (
        jnp.full((b, h_dim), NEG_INF, jnp.float32),
        jnp.zeros((b, h_dim), jnp.float32),
        jnp.zeros((b, h_dim, dh), jnp.float32),
    )
    (m_f, l_f, acc_f), _ = jax.lax.scan(step, init, (cols, mrow))
    out = acc_f / jnp.maximum(l_f, 1e-9)[:, :, None]  # [B, H, Dh]
    return out.astype(h_src.dtype)


MULTILANE_BACKENDS = ("reference", "kernel", "kernel_interpret", "fused_fp", "fused_fp_interpret")

# string-backend twin of fusion.cpu_fallback: compiled Pallas lowering
# needs a TPU; the interpreter runs the identical kernel body on CPU.
_CPU_BACKEND_FALLBACK = {"kernel": "kernel_interpret", "fused_fp": "fused_fp_interpret"}


def resolve_multilane_backend(backend: str) -> str:
    """Degrade a compiled multilane backend string to its interpret twin on
    CPU-only hosts (same kernel, same numbers)."""
    if backend in _CPU_BACKEND_FALLBACK and jax.default_backend() == "cpu":
        return _CPU_BACKEND_FALLBACK[backend]
    return backend


def multilane_na(
    plan: MultiLanePlan,
    theta_src: jnp.ndarray | None,  # [G, Ns_pad, H]   (None with fused_fp)
    theta_dst: jnp.ndarray | None,  # [G, Nd_pad, H]   (None with fused_fp)
    h_src: jnp.ndarray | None,      # [Ns_pad, H, Dh]  (None with fused_fp)
    *,
    edge_bias: jnp.ndarray | None = None,  # [G, H]
    leaky_slope: float = 0.2,
    backend: str = "reference",
    fp: FusedFPInputs | None = None,
) -> jnp.ndarray:
    """Run NA for all semantic graphs across lanes.

    Returns z [G, Nd_pad, H, Dh].

    ``backend`` selects the per-unit executor:
      * ``"reference"`` — vmap over (lanes, units) of the scan oracle;
      * ``"kernel"`` — one fused Pallas launch for *all* lanes' units
        (kernels/seg_gat_agg_multigraph): the paper's mixed-graph lane
        datapath as a single TPU kernel;
      * ``"kernel_interpret"`` — same kernel under the Pallas interpreter
        (CPU validation / CI);
      * ``"fused_fp"`` / ``"fused_fp_interpret"`` — the stage-fusion
        megakernel (kernels/seg_gat_agg_fused_fp): pass
        ``fp=FusedFPInputs`` (raw features padded to [N_pad, Din] +
        projection/attention params) and leave the theta/h operands None;
        the FP stage runs inside the launch (DESIGN.md §10).
    All backends scatter identically, so they agree to f32 tolerance.
    """
    if backend not in MULTILANE_BACKENDS:
        raise ValueError(f"backend={backend!r}, expected one of {MULTILANE_BACKENDS}")
    fused_fp = backend in ("fused_fp", "fused_fp_interpret")
    if fused_fp:
        if fp is None:
            raise ValueError(f"backend={backend!r} needs fp=FusedFPInputs")
        g_n, h_dim, dh = fp.a_src.shape
        out_dtype = fp.x.dtype
    else:
        g_n, _, h_dim = theta_src.shape
        dh = h_src.shape[-1]
        out_dtype = h_src.dtype
    if edge_bias is None:
        edge_bias = jnp.zeros((g_n, h_dim), out_dtype)

    lanes, units, w = plan.col_index.shape
    with trace_span(
        "na/multilane", stage="NA", backend=backend, lanes=lanes,
        units=units, graphs=g_n,
    ) as sp:
        if backend == "reference":
            unit_fn = lambda c, m, g, r: _unit_na(
                c, m, g, r, theta_src, theta_dst, h_src, edge_bias, leaky_slope
            )
            per_unit = jax.vmap(jax.vmap(unit_fn))(
                plan.col_index, plan.masks, plan.graph_id, plan.dst_row
            )  # [L, U, B, H, Dh]
        elif fused_fp:
            from repro.kernels.seg_gat_agg_fused_fp import seg_gat_agg_fused_fp

            flat = seg_gat_agg_fused_fp(
                plan.col_index.reshape(lanes * units, w),
                plan.graph_id.reshape(lanes * units),
                plan.dst_row.reshape(lanes * units),
                fp.wsel,
                plan.masks.reshape(lanes * units, w, plan.block, plan.block),
                fp.x, fp.w, fp.b, fp.a_src, fp.a_dst, edge_bias,
                leaky_slope=leaky_slope,
                interpret=(backend == "fused_fp_interpret"),
            )  # [L*U*B, H, Dh]
            per_unit = flat.reshape(lanes, units, plan.block, h_dim, dh)
        else:
            from repro.kernels.seg_gat_agg_multigraph import seg_gat_agg_multigraph

            flat = seg_gat_agg_multigraph(
                plan.col_index.reshape(lanes * units, w),
                plan.graph_id.reshape(lanes * units),
                plan.dst_row.reshape(lanes * units),
                plan.masks.reshape(lanes * units, w, plan.block, plan.block),
                theta_src,
                theta_dst,
                h_src,
                edge_bias,
                leaky_slope=leaky_slope,
                interpret=(backend == "kernel_interpret"),
            )  # [L*U*B, H, Dh]
            per_unit = flat.reshape(lanes, units, plan.block, h_dim, dh)

        out = jnp.zeros((g_n, plan.n_dst_blocks, plan.block, h_dim, dh), out_dtype)
        contrib = jnp.where(plan.valid[:, :, None, None, None], per_unit, 0.0)
        out = out.at[plan.graph_id, plan.dst_row].add(contrib)
        return sp.sync(out.reshape(g_n, plan.n_dst_blocks * plan.block, h_dim, dh))


def multilane_na_sharded(
    plan: MultiLanePlan,
    theta_src: jnp.ndarray | None,  # [G, Ns_pad, H]   (None with fused_fp)
    theta_dst: jnp.ndarray | None,  # [G, Nd_pad, H]   (None with fused_fp)
    h_src: jnp.ndarray | None,      # [Ns_pad, H, Dh]  (None with fused_fp)
    *,
    mesh,
    lane_axes: tuple[str, ...] = ("lane",),
    edge_bias: jnp.ndarray | None = None,  # [G, H]
    leaky_slope: float = 0.2,
    backend: str = "reference",
    fp: FusedFPInputs | None = None,
) -> jnp.ndarray:
    """``multilane_na`` with the lane dimension dispatched over mesh chips.

    The plan's lane axis is `shard_map`ped over ``lane_axes`` (paper
    §4.2.1: adding hardware = adding devices to the lane axis).  Each
    shard runs its local lanes' work units against the *replicated*
    projected features — every lane gathers what it needs from the shared
    FP output, the functional RAB of DESIGN.md §2 — and scatters into a
    zero-initialised full dst space; a single psum over the lane axes is
    the only cross-lane communication (the GSF barrier).

    Numerically identical to ``multilane_na`` for any lane-axis size that
    divides the plan's lane count (size 1 = the vmap path, exactly).
    """
    n_shards = math.prod(mesh.shape[a] for a in lane_axes)
    assert plan.num_lanes % n_shards == 0, (plan.num_lanes, n_shards)
    fused_fp = backend in ("fused_fp", "fused_fp_interpret")
    if fused_fp:
        if fp is None:
            raise ValueError(f"backend={backend!r} needs fp=FusedFPInputs")
        g_n, h_dim, _ = fp.a_src.shape
        bias_dtype = fp.x.dtype
    else:
        g_n, _, h_dim = theta_src.shape
        bias_dtype = h_src.dtype
    if edge_bias is None:
        edge_bias = jnp.zeros((g_n, h_dim), bias_dtype)

    lane_part = lane_axes[0] if len(lane_axes) == 1 else tuple(lane_axes)
    lane_spec = lambda ndim: PartitionSpec(lane_part, *([None] * (ndim - 1)))
    plan_specs = MultiLanePlan(
        col_index=lane_spec(3),
        masks=lane_spec(5),
        graph_id=lane_spec(2),
        dst_row=lane_spec(2),
        valid=lane_spec(2),
        block=plan.block,
        num_graphs=plan.num_graphs,
        n_dst_blocks=plan.n_dst_blocks,
        lane_plan=None,
    )
    rep = PartitionSpec()

    if fused_fp:
        # raw features + weight tables replicate like the thetas do: every
        # lane shard projects the tiles its units touch on-chip (the
        # functional RAB, now fed from raw x instead of materialized h')
        fp_specs = jax.tree_util.tree_map(lambda _: rep, fp)

        def local_fp(plan_loc, fp_loc, bias):
            partial = multilane_na(
                plan_loc, None, None, None, edge_bias=bias,
                leaky_slope=leaky_slope, backend=backend, fp=fp_loc,
            )
            return jax.lax.psum(partial, lane_axes)

        fn = shard_map(
            local_fp,
            mesh=mesh,
            in_specs=(plan_specs, fp_specs, rep),
            out_specs=rep,
            check_rep=False,
        )
        with trace_span(
            "na/multilane_sharded", stage="NA", backend=backend,
            shards=n_shards, lanes=plan.num_lanes, graphs=g_n, fused_fp=True,
        ) as sp:
            return sp.sync(fn(plan, fp, edge_bias))

    def local(plan_loc, ths, thd, hs, bias):
        # backend applies per shard: "kernel" = one fused Pallas launch
        # per chip over that chip's lanes, shard_map across chips.
        partial = multilane_na(
            plan_loc, ths, thd, hs, edge_bias=bias, leaky_slope=leaky_slope,
            backend=backend,
        )
        return jax.lax.psum(partial, lane_axes)

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(plan_specs, rep, rep, rep, rep),
        out_specs=rep,
        check_rep=False,
    )
    with trace_span(
        "na/multilane_sharded", stage="NA", backend=backend,
        shards=n_shards, lanes=plan.num_lanes, graphs=g_n,
    ) as sp:
        return sp.sync(fn(plan, theta_src, theta_dst, h_src, edge_bias))
