"""Data-reusability accounting — the RAB made functional (paper §4.3.1).

In HiHGNN a redundancy-aware bitmap guards recomputation of projected
features h' and attention coefficients theta.  In a functional framework
the program is *factored* so redundant work is never expressed: h' is
computed once per vertex type, theta once per (vertex, semantic graph),
and everything else gathers.  What remains observable — and what the
paper's Fig. 15 measures — is *memory traffic*: whether the projected
features a semantic graph needs are still resident in the FP buffer left
by the previous graph (reuse) or must be re-fetched from HBM (miss).

``fp_buffer_traffic`` simulates exactly that: an FP-Buf of given capacity
holding per-type projected feature tables, consumed in a given execution
order.  It returns reused vs re-fetched bytes, which benchmarks/similarity.py
sweeps across (total-features / FP-Buf) ratios to reproduce Fig. 15.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

from ..graphs.hetgraph import SemanticGraph


@dataclasses.dataclass
class ReuseCounters:
    """Work counters with and without RAB-style dedup."""

    fp_naive: int = 0      # vertex projections if recomputed per semantic graph
    fp_dedup: int = 0      # vertex projections with type-level dedup (ours)
    theta_naive: int = 0   # coefficient computations if recomputed per edge
    theta_dedup: int = 0   # coefficient computations once per (vertex, graph)

    @property
    def fp_saved(self) -> float:
        return 1.0 - self.fp_dedup / max(self.fp_naive, 1)

    @property
    def theta_saved(self) -> float:
        return 1.0 - self.theta_dedup / max(self.theta_naive, 1)


def count_reuse(sgs: Sequence[SemanticGraph], vertex_counts: Mapping[str, int]) -> ReuseCounters:
    c = ReuseCounters()
    projected_types: set[str] = set()
    for sg in sgs:
        for t in set(sg.path_types) & {sg.src_type, sg.dst_type}:
            c.fp_naive += vertex_counts[t]
            if t not in projected_types:
                c.fp_dedup += vertex_counts[t]
                projected_types.add(t)
        # naive: recompute theta_dst and theta_src per edge endpoint
        c.theta_naive += 2 * sg.num_edges
        c.theta_dedup += sg.num_src + sg.num_dst
    return c


@dataclasses.dataclass(frozen=True)
class FPTraffic:
    reused_bytes: int
    fetched_bytes: int

    @property
    def total(self) -> int:
        return self.reused_bytes + self.fetched_bytes

    @property
    def reuse_fraction(self) -> float:
        return self.reused_bytes / max(self.total, 1)


def fp_buffer_traffic(
    order: Sequence[int],
    sgs: Sequence[SemanticGraph],
    vertex_counts: Mapping[str, int],
    *,
    bytes_per_vertex: Mapping[str, int],
    fpbuf_bytes: int,
) -> FPTraffic:
    """Simulate FP-Buf residency across an execution order of semantic graphs.

    Each semantic graph needs the projected tables of every type on its
    metapath.  Table bytes still resident from the previous graphs are
    reused; the rest are fetched.  Eviction is LRU at table granularity.
    A table larger than the whole buffer can never be fully resident: the
    buffer retains as much of it as fits (a prefix of its blocks) and on
    the next access that resident part is reused while only the missing
    remainder is re-fetched — partial-block refetch, matching the serving
    tier's block-granular FP cache (serve/fp_cache.py) rather than
    charging a full miss.
    """
    resident: dict[str, int] = {}  # type -> resident bytes (<= table size)
    lru: list[str] = []
    reused = 0
    fetched = 0
    for gi in order:
        sg = sgs[gi]
        for t in dict.fromkeys(sg.path_types):  # stable unique
            size = vertex_counts[t] * bytes_per_vertex[t]
            have = min(resident.pop(t, 0), size)
            if t in lru:
                lru.remove(t)
            reused += have
            fetched += size - have
            want = min(size, fpbuf_bytes)  # partial residency if size > buf
            if want == 0:
                continue
            while sum(resident.values()) + want > fpbuf_bytes and lru:
                evict = lru.pop(0)
                del resident[evict]
            resident[t] = want
            lru.append(t)
    return FPTraffic(reused_bytes=reused, fetched_bytes=fetched)
