"""Scheduling algorithms of HiHGNN (host-side preprocessing, numpy).

1. Similarity-aware execution scheduling (paper §4.3.2): build the
   similarity hypergraph over semantic graphs (edge weight
   w_e = 1 - eta_e / sum(eta), eta_e = #vertices of shared types), add two
   virtual endpoints with zero-weight edges, make the graph complete with
   weight-1 filler edges, and order execution by the shortest Hamilton
   path (exact Held-Karp DP — #semantic graphs <= ~16 in practice, and the
   paper measures <0.1% preprocessing overhead on CPU).

2. Workload-aware scheduling (paper §4.2.2): balance edge workloads across
   lanes.  Units of work are dst-block rows (each dst vertex lives in
   exactly one unit, so no cross-lane NA reduction is needed); rows whose
   lane would exceed the allocation threshold spill to the overflow list
   (OW) and are re-assigned to under-loaded lanes, exactly mirroring the
   paper's Local Scheduler.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Mapping, Sequence

import numpy as np

from ..graphs.hetgraph import SemanticGraph


# ---------------------------------------------------------------------------
# Similarity-aware execution scheduling
# ---------------------------------------------------------------------------

def shared_vertex_count(a: SemanticGraph, b: SemanticGraph, vertex_counts: Mapping[str, int]) -> int:
    """eta_e: number of vertices whose projected features both graphs touch
    (vertices of vertex types appearing on both metapaths)."""
    shared = set(a.path_types) & set(b.path_types)
    return int(sum(vertex_counts[t] for t in shared))


def similarity_matrix(sgs: Sequence[SemanticGraph], vertex_counts: Mapping[str, int]) -> np.ndarray:
    """Paper's weights: w_e = 1 - eta_e / sum_i eta_i over real edges; pairs
    with no shared type get weight 1 (the 'completing' gray edges).
    Lower weight == higher similarity == more FP reuse."""
    n = len(sgs)
    eta = np.zeros((n, n))
    for i in range(n):
        for j in range(i + 1, n):
            eta[i, j] = eta[j, i] = shared_vertex_count(sgs[i], sgs[j], vertex_counts)
    total = eta.sum() / 2.0
    w = np.ones((n, n))
    if total > 0:
        nz = eta > 0
        w[nz] = 1.0 - eta[nz] / total
    np.fill_diagonal(w, 0.0)
    return w


def shortest_hamilton_path(w: np.ndarray) -> tuple[list[int], float]:
    """Exact shortest open Hamilton path via Held-Karp DP.

    The paper's two virtual endpoints connected to everything with weight 0
    make the closed-tour formulation equivalent to the min-cost *open* path
    over all (start, end) pairs — which is what this DP computes directly.
    """
    n = w.shape[0]
    if n == 0:
        return [], 0.0
    if n == 1:
        return [0], 0.0
    full = 1 << n
    INF = float("inf")
    dp = np.full((full, n), INF)
    parent = np.full((full, n), -1, np.int32)
    for i in range(n):
        dp[1 << i, i] = 0.0
    for mask in range(full):
        for last in range(n):
            cur = dp[mask, last]
            if cur == INF or not (mask >> last) & 1:
                continue
            rest = ~mask & (full - 1)
            nxt = rest
            while nxt:
                j = (nxt & -nxt).bit_length() - 1
                nxt &= nxt - 1
                nm = mask | (1 << j)
                cand = cur + w[last, j]
                if cand < dp[nm, j]:
                    dp[nm, j] = cand
                    parent[nm, j] = last
    end = int(np.argmin(dp[full - 1]))
    cost = float(dp[full - 1, end])
    order = [end]
    mask = full - 1
    while parent[mask, order[-1]] >= 0:
        p = int(parent[mask, order[-1]])
        mask ^= 1 << order[-1]
        order.append(p)
    order.reverse()
    return order, cost


def brute_force_hamilton_path(w: np.ndarray) -> tuple[list[int], float]:
    """O(n!) oracle for property tests (n <= 7)."""
    n = w.shape[0]
    best, best_cost = list(range(n)), float("inf")
    for perm in itertools.permutations(range(n)):
        c = sum(w[perm[i], perm[i + 1]] for i in range(n - 1))
        if c < best_cost:
            best, best_cost = list(perm), c
    return best, best_cost


def similarity_schedule(
    sgs: Sequence[SemanticGraph], vertex_counts: Mapping[str, int]
) -> tuple[list[int], np.ndarray]:
    """Execution order of semantic graphs maximizing consecutive FP reuse."""
    w = similarity_matrix(sgs, vertex_counts)
    order, _ = shortest_hamilton_path(w)
    return order, w


# ---------------------------------------------------------------------------
# Workload-aware scheduling (lane balancing)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LanePlan:
    """Static lane assignment of work units.

    unit_graph[u], unit_row[u]: which (semantic graph, dst-block row) unit u is.
    unit_lane[u]: the lane executing it.
    lane_load[l]: total edges on lane l.
    """

    unit_graph: np.ndarray
    unit_row: np.ndarray
    unit_cost: np.ndarray
    unit_lane: np.ndarray
    lane_load: np.ndarray

    @property
    def num_lanes(self) -> int:
        return int(self.lane_load.shape[0])

    def imbalance(self) -> float:
        """max/mean lane load — 1.0 is perfect balance."""
        mean = self.lane_load.mean()
        return float(self.lane_load.max() / max(mean, 1e-9))


def lane_assignment(
    row_costs: Sequence[np.ndarray],
    num_lanes: int,
    *,
    threshold: float | None = None,
) -> LanePlan:
    """Workload-aware scheduling over dst-block-row work units.

    ``row_costs[g][r]`` = #edges of row r of semantic graph g.  Graph g's
    rows start on lane ``g % num_lanes`` (the paper assigns W_i to Lane_i);
    rows that would push the lane past the threshold go to the overflow
    list (OW) and are then greedily placed on the least-loaded lanes
    (largest first).  Threshold defaults to ceil(total/num_lanes).
    """
    units_g, units_r, units_c = [], [], []
    for g, rc in enumerate(row_costs):
        for r, c in enumerate(np.asarray(rc)):
            units_g.append(g)
            units_r.append(r)
            units_c.append(float(c))
    unit_graph = np.asarray(units_g, np.int32)
    unit_row = np.asarray(units_r, np.int32)
    unit_cost = np.asarray(units_c)
    total = unit_cost.sum()
    if threshold is None:
        threshold = float(np.ceil(total / max(num_lanes, 1)))

    lane_load = np.zeros(num_lanes)
    unit_lane = np.full(unit_graph.shape[0], -1, np.int32)
    overflow: list[int] = []
    # phase 1: home-lane assignment up to threshold
    for u in range(unit_graph.shape[0]):
        home = int(unit_graph[u]) % num_lanes
        if lane_load[home] + unit_cost[u] <= threshold:
            unit_lane[u] = home
            lane_load[home] += unit_cost[u]
        else:
            overflow.append(u)
    # phase 2: overflow to least-loaded lanes, largest units first (LPT)
    for u in sorted(overflow, key=lambda i: -unit_cost[i]):
        l = int(np.argmin(lane_load))
        unit_lane[u] = l
        lane_load[l] += unit_cost[u]
    return LanePlan(unit_graph, unit_row, unit_cost, unit_lane, lane_load)


def naive_lane_assignment(row_costs: Sequence[np.ndarray], num_lanes: int) -> LanePlan:
    """Baseline without workload-aware scheduling: graph g entirely on lane
    g % num_lanes (the paper's 'w/o' ablation)."""
    units_g, units_r, units_c = [], [], []
    for g, rc in enumerate(row_costs):
        for r, c in enumerate(np.asarray(rc)):
            units_g.append(g)
            units_r.append(r)
            units_c.append(float(c))
    unit_graph = np.asarray(units_g, np.int32)
    unit_row = np.asarray(units_r, np.int32)
    unit_cost = np.asarray(units_c)
    unit_lane = (unit_graph % num_lanes).astype(np.int32)
    lane_load = np.zeros(num_lanes)
    np.add.at(lane_load, unit_lane, unit_cost)
    return LanePlan(unit_graph, unit_row, unit_cost, unit_lane, lane_load)
