"""Deterministic, shardable, checkpointable data pipelines.

Counter-based PRNG (threefry keyed on (seed, step)) means batch t is a
pure function of the pipeline state — restarting from a checkpoint replays
the exact token stream, which is what makes checkpoint/restart bitwise
reproducible (tests/test_train assert this).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SyntheticLMData:
    """Synthetic next-token data with planted n-gram structure so training
    loss actually decreases (not pure noise)."""

    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    step: int = 0
    with_frames: bool = False      # audio stub frontend
    frame_len: int = 0
    d_model: int = 0

    def state(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def restore(self, state: dict) -> None:
        self.step = int(state["step"])
        assert int(state["seed"]) == self.seed, "pipeline seed mismatch"

    def next(self) -> dict:
        key = jax.random.fold_in(jax.random.key(self.seed), self.step)
        self.step += 1
        k1, k2, k3 = jax.random.split(key, 3)
        b, s, v = self.global_batch, self.seq_len + 1, self.vocab_size
        base = jax.random.randint(k1, (b, (s + 1) // 2), 0, v)
        # plant structure: every token is emitted twice — a trivially
        # learnable copy task, so smoke training shows decreasing loss fast
        toks = jnp.stack([base, base], axis=-1).reshape(b, -1)[:, :s]
        batch = {"tokens": toks.astype(jnp.int32)}
        if self.with_frames:
            batch["frames"] = (
                jax.random.normal(k2, (b, self.frame_len, self.d_model), jnp.float32) * 0.2
            ).astype(jnp.bfloat16)
        del k3
        return batch


@dataclasses.dataclass
class SyntheticHGNNData:
    """Counter-based labeled-vertex minibatch stream for transductive HGNN
    training (HAN/R-GAT train full-graph forward, minibatch loss).

    Same checkpoint contract as :class:`SyntheticLMData`: batch t is a pure
    function of ``(seed, step)`` (threefry fold-in), so a crashed run that
    restores ``state()`` from the checkpoint aux replays the exact vertex
    stream — the HGNN trainer inherits the bitwise resume guarantee
    (tests/test_hgnn_train).  ``batch_size >= num_vertices`` degenerates to
    the full labeled set in a fixed order (full-batch transductive
    training, still one batch per step so the loop shape is unchanged).
    """

    num_vertices: int
    batch_size: int
    seed: int = 0
    step: int = 0

    def __post_init__(self):
        assert self.num_vertices > 0 and self.batch_size > 0

    def state(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def restore(self, state: dict) -> None:
        self.step = int(state["step"])
        assert int(state["seed"]) == self.seed, "pipeline seed mismatch"

    def next(self) -> dict:
        key = jax.random.fold_in(jax.random.key(self.seed), self.step)
        self.step += 1
        if self.batch_size >= self.num_vertices:
            idx = jnp.arange(self.num_vertices, dtype=jnp.int32)
        else:
            idx = jax.random.permutation(key, self.num_vertices)[: self.batch_size]
        return {"idx": idx.astype(jnp.int32)}


def hgnn_minibatches(num_vertices: int, batch_size: int, seed: int = 0):
    """Deterministic vertex-minibatch id stream for HGNN training."""
    rng = np.random.default_rng(seed)
    while True:
        perm = rng.permutation(num_vertices)
        for i in range(0, num_vertices - batch_size + 1, batch_size):
            yield perm[i : i + batch_size].astype(np.int32)
