from .pipeline import SyntheticHGNNData, SyntheticLMData, hgnn_minibatches

__all__ = ["SyntheticHGNNData", "SyntheticLMData", "hgnn_minibatches"]
