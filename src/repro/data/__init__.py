from .pipeline import SyntheticLMData, hgnn_minibatches

__all__ = ["SyntheticLMData", "hgnn_minibatches"]
