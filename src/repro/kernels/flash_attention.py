"""Causal/local GQA flash attention (Pallas TPU).

The same online-softmax decomposition the paper uses for the NA stage
(Fig. 6) applied to dense attention: numerator and denominator accumulate
simultaneously per query tile, so no S×S score matrix ever exists.  Used
by every attention-bearing assigned architecture; ``window`` implements
recurrentgemma's local attention.

Grid: (B, Hq, Sq/BQ, Sk/BK); the key axis is sequential (scratch carries
m/l/acc); batch, head and query-block axes are parallel.  GQA maps query
head h to kv head h // (Hq/Hkv) in the k/v index maps — kv tiles are
fetched once per group by the pipeline, the VMEM analogue of the paper's
coefficient reuse across edges sharing an endpoint.

VMEM per step (BQ=BK=512, Dh=128, bf16 in / f32 acc):
q 128 KB + k/v 256 KB + acc/m/l ~260 KB ≈ 0.7 MB « 16 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams

NEG_INF = -1e30


def _kernel(
    q_ref,    # [1, 1, BQ, Dh]
    k_ref,    # [1, 1, BK, Dh]
    v_ref,    # [1, 1, BK, Dh]
    o_ref,    # [1, 1, BQ, Dh]
    acc_ref,  # [BQ, Dh] f32
    m_ref,    # [BQ] f32
    l_ref,    # [BQ] f32
    *,
    scale: float,
    causal: bool,
    window: int | None,
    block_q: int,
    block_k: int,
    q_offset: int,
):
    ik = pl.program_id(3)
    nk = pl.num_programs(3)
    iq = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale  # [BQ, Dh]
    k = k_ref[0, 0].astype(jnp.float32)          # [BK, Dh]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # [BQ, BK]

    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0) + q_offset
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), bool)
    if causal:
        mask = jnp.logical_and(mask, kpos <= qpos)
    if window is not None:
        mask = jnp.logical_and(mask, kpos > qpos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)

    v = v_ref[0, 0].astype(jnp.float32)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0, 0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-9)[:, None]
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,  # [B, Hq, Sq, Dh]
    k: jnp.ndarray,  # [B, Hkv, Sk, Dh]
    v: jnp.ndarray,  # [B, Hkv, Sk, Dh]
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    b, hq, sq, dh = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    assert hq % hkv == 0
    group = hq // hkv
    scale = scale if scale is not None else dh ** -0.5
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    assert sq % bq == 0 and sk % bk == 0
    grid = (b, hq, sq // bq, sk // bk)
    q_offset = sk - sq  # align the last query with the last key

    out = pl.pallas_call(
        functools.partial(
            _kernel,
            scale=scale,
            causal=causal,
            window=window,
            block_q=bq,
            block_k=bk,
            q_offset=q_offset,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda b_, h, i, j: (b_, h // group, j, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda b_, h, i, j: (b_, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh), lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, dh), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="flash_attention",
    )(q, k, v)
    return out
