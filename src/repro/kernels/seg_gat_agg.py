"""Fused block-sparse online-softmax neighbor aggregation (Pallas TPU).

This is the paper's fused NA datapath (§4.1.2, Fig. 6/7) adapted to the
TPU: the irregular edge-centric stream of the accelerator becomes a
block-densified sweep over the non-empty B×B (dst × src) adjacency blocks
of a semantic graph.  Per dst-block row the kernel keeps the running
numerator (acc), denominator (l) and max (m) resident in VMEM — the
paper's softmax decomposition "aggregate the numerator immediately and
accumulate it onto the denominator" (Fig. 6), made numerically stable with
a running max — and only writes the finished aggregate once per row.

Tiling (VMEM working set per grid step, B = 128, Dh <= 128, fp32):
    mask block     B×B           64 KB
    theta tiles    2×B           1 KB
    h_src tile     B×Dh          64 KB
    acc/m/l        B×Dh + 2B     65 KB
  ≈ 200 KB « 16 MB VMEM; MXU sees B×B @ B×Dh matmuls (128-aligned).

Grid: (H, R, W) = (heads, dst-block rows, max blocks per row); the W axis
is sequential ("arbitrary") because scratch carries across it; H and R are
embarrassingly parallel.  The block-column indices arrive via scalar
prefetch so the src tiles for step w+1 can be fetched while step w
computes (the accelerator's FP-Buf prefetch, done by the Pallas pipeline).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams

NEG_INF = -1e30


def _kernel(
    # scalar prefetch
    col_ref,      # int32 [R, W]
    bias_ref,     # float32 [H]
    # inputs
    mask_ref,     # bool  [1, 1, B, B]
    thd_ref,      # f32   [B, 1]
    ths_ref,      # f32   [B, 1]
    hs_ref,       # f32   [B, 1, Dh]
    # output
    out_ref,      # f32   [B, 1, Dh]
    # scratch
    acc_ref,      # f32   [B, Dh]
    m_ref,        # f32   [B]
    l_ref,        # f32   [B]
    *,
    leaky_slope: float,
):
    h = pl.program_id(0)
    r = pl.program_id(1)
    w = pl.program_id(2)
    nw = pl.num_programs(2)

    @pl.when(w == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    col = col_ref[r, w]
    live = jnp.logical_and(mask_ref[0, 0], col >= 0)  # [B, B]

    thd = thd_ref[:, 0]  # [B] dst coefficients
    ths = ths_ref[:, 0]  # [B] src coefficients
    logits = thd[:, None] + ths[None, :] + bias_ref[h]
    logits = jnp.where(logits >= 0, logits, leaky_slope * logits)  # LeakyReLU
    logits = jnp.where(live, logits, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1))
    scale = jnp.exp(m_prev - m_new)  # [B]
    p = jnp.exp(logits - m_new[:, None])
    p = jnp.where(live, p, 0.0)

    l_ref[...] = l_ref[...] * scale + jnp.sum(p, axis=1)
    hs = hs_ref[:, 0, :].astype(jnp.float32)  # [B, Dh]
    acc_ref[...] = acc_ref[...] * scale[:, None] + jnp.dot(
        p, hs, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(w == nw - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-9)
        out_ref[:, 0, :] = (acc_ref[...] / denom[:, None]).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("leaky_slope", "interpret"))
def seg_gat_agg(
    col_index: jnp.ndarray,  # int32 [R, W]
    masks: jnp.ndarray,      # bool  [R, W, B, B]
    theta_src: jnp.ndarray,  # f32   [Ns_pad, H]
    theta_dst: jnp.ndarray,  # f32   [Nd_pad, H]
    h_src: jnp.ndarray,      # f32   [Ns_pad, H, Dh]
    *,
    leaky_slope: float = 0.2,
    edge_bias: jnp.ndarray | float = 0.0,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns the attention-aggregated features [Nd_pad, H, Dh].

    Contract (guaranteed by graphs.formats.to_block_csr): column indices
    are unique within each row — duplicate columns would double-count
    their masked edges in the online accumulation."""
    R, W = col_index.shape
    B = masks.shape[-1]
    ns_pad, H = theta_src.shape
    Dh = h_src.shape[-1]
    assert theta_dst.shape == (R * B, H)
    assert h_src.shape == (ns_pad, H, Dh)

    bias = jnp.broadcast_to(jnp.asarray(edge_bias, jnp.float32), (H,))

    grid = (H, R, W)

    def mask_map(h, r, w, col, bias_r):
        return (r, w, 0, 0)

    def thd_map(h, r, w, col, bias_r):
        return (r, h)

    def ths_map(h, r, w, col, bias_r):
        return (jnp.maximum(col[r, w], 0), h)

    def hs_map(h, r, w, col, bias_r):
        return (jnp.maximum(col[r, w], 0), h, 0)

    def out_map(h, r, w, col, bias_r):
        return (r, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, B, B), mask_map),
            pl.BlockSpec((B, 1), thd_map),
            pl.BlockSpec((B, 1), ths_map),
            pl.BlockSpec((B, 1, Dh), hs_map),
        ],
        out_specs=pl.BlockSpec((B, 1, Dh), out_map),
        scratch_shapes=[
            pltpu.VMEM((B, Dh), jnp.float32),
            pltpu.VMEM((B,), jnp.float32),
            pltpu.VMEM((B,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, leaky_slope=leaky_slope),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R * B, H, Dh), h_src.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
        name="seg_gat_agg",
    )(col_index, bias, masks, theta_dst, theta_src, h_src)
    return out
