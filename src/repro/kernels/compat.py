"""Pallas-TPU API compatibility.

`TPUCompilerParams` (jax <= 0.4.x / 0.5.x) was renamed to
`CompilerParams` in newer releases; resolve whichever this jax ships so
the kernels build against both.
"""
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["CompilerParams"]
