"""Pure-jnp oracles for every Pallas kernel (deliberately naive/dense)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def ref_seg_gat_agg(
    col_index: jnp.ndarray,  # int32 [R, W]
    masks: jnp.ndarray,      # bool [R, W, B, B]
    theta_src: jnp.ndarray,  # [Ns_pad, H]
    theta_dst: jnp.ndarray,  # [Nd_pad, H]
    h_src: jnp.ndarray,      # [Ns_pad, H, Dh]
    *,
    leaky_slope: float = 0.2,
    edge_bias: jnp.ndarray | float = 0.0,
) -> jnp.ndarray:
    """Densify the block-CSR adjacency and do textbook softmax attention."""
    R, W = col_index.shape
    B = masks.shape[-1]
    nd, ns = R * B, theta_src.shape[0]
    nblk = ns // B
    # dense adjacency [Nd, Ns]
    adj = jnp.zeros((nd, ns), bool)
    for r in range(R):
        for w in range(W):
            c = int(col_index[r, w])
            if c < 0:
                continue
            adj = adj.at[r * B : (r + 1) * B, c * B : (c + 1) * B].set(
                jnp.logical_or(adj[r * B : (r + 1) * B, c * B : (c + 1) * B], masks[r, w])
            )
    logits = jax.nn.leaky_relu(
        theta_dst[:, None, :] + theta_src[None, :, :] + edge_bias, leaky_slope
    )  # [Nd, Ns, H]
    logits = jnp.where(adj[:, :, None], logits, NEG_INF)
    m = jnp.maximum(logits.max(axis=1, keepdims=True), NEG_INF)
    p = jnp.where(adj[:, :, None], jnp.exp(logits - m), 0.0)
    denom = p.sum(axis=1)  # [Nd, H]
    num = jnp.einsum("dsh,shf->dhf", p, h_src)
    del nblk
    return num / jnp.maximum(denom, 1e-9)[:, :, None]


def ref_fused_fp_coeff(
    x: jnp.ndarray,      # [N, Din]
    w: jnp.ndarray,      # [Din, H*Dh]
    b: jnp.ndarray,      # [H*Dh]
    a_src: jnp.ndarray,  # [H, Dh]
    a_dst: jnp.ndarray,  # [H, Dh]
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    h = (x @ w + b).reshape(x.shape[0], a_src.shape[0], a_src.shape[1])
    th_s = jnp.einsum("nhd,hd->nh", h, a_src)
    th_d = jnp.einsum("nhd,hd->nh", h, a_dst)
    return h.reshape(x.shape[0], -1), th_s, th_d


def ref_flash_attention(
    q: jnp.ndarray,  # [B, Hq, Sq, Dh]
    k: jnp.ndarray,  # [B, Hkv, Sk, Dh]
    v: jnp.ndarray,  # [B, Hkv, Sk, Dh]
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
) -> jnp.ndarray:
    b, hq, sq, dh = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    group = hq // hkv
    scale = scale if scale is not None else dh ** -0.5
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    qpos = jnp.arange(sq)[:, None] + (sk - sq)  # align last q with last k
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v).astype(q.dtype)
