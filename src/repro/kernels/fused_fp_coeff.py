"""Fused Feature Projection + attention-coefficient kernel (Pallas TPU).

Paper §4.1.1 modification (1): the attention-coefficient computation
(Alg. 2 line 8) is fused into the FP stage — the moment a tile of h' is
produced by the MXU it is immediately contracted with a_src/a_dst, without
a round-trip to HBM.  One pass over x yields (h', theta_src, theta_dst).

Tiling: grid (N/BN, Din/BK).  The K axis is sequential with an f32 VMEM
accumulator; the N axis is parallel.  On the last K step the kernel adds
the bias, emits h', and computes both coefficient vectors per head while
the h' tile is still VMEM-resident (the accelerator's FP-Buf residency).

Working set (BN=256, BK=512, H*Dh=512, fp32): x 512 KB + w 1 MB +
acc/h' 512 KB ≈ 2 MB « 16 MB VMEM; matmul dims all 128-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams


def _kernel(
    x_ref,      # [BN, BK]
    w_ref,      # [BK, HDh]
    b_ref,      # [1, HDh]
    asrc_ref,   # [H, Dh]
    adst_ref,   # [H, Dh]
    h_ref,      # out [BN, HDh]
    ths_ref,    # out [BN, H]
    thd_ref,    # out [BN, H]
    acc_ref,    # scratch [BN, HDh] f32
    *,
    heads: int,
    head_dim: int,
):
    k = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == nk - 1)
    def _finalize():
        h = acc_ref[...] + b_ref[0, :].astype(jnp.float32)  # [BN, HDh]
        h_ref[...] = h.astype(h_ref.dtype)
        # coefficients per head while h' is VMEM-resident
        for hd in range(heads):
            seg = h[:, hd * head_dim : (hd + 1) * head_dim]  # [BN, Dh]
            ths_ref[:, hd] = jnp.dot(
                seg, asrc_ref[hd, :].astype(jnp.float32),
                preferred_element_type=jnp.float32,
            ).astype(ths_ref.dtype)
            thd_ref[:, hd] = jnp.dot(
                seg, adst_ref[hd, :].astype(jnp.float32),
                preferred_element_type=jnp.float32,
            ).astype(thd_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_k", "interpret")
)
def fused_fp_coeff(
    x: jnp.ndarray,      # [N, Din]
    w: jnp.ndarray,      # [Din, H*Dh]
    b: jnp.ndarray,      # [H*Dh]
    a_src: jnp.ndarray,  # [H, Dh]
    a_dst: jnp.ndarray,  # [H, Dh]
    *,
    block_n: int = 256,
    block_k: int = 512,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (h' [N, H*Dh], theta_src [N, H], theta_dst [N, H])."""
    n, din = x.shape
    hdh = w.shape[1]
    heads, head_dim = a_src.shape
    assert heads * head_dim == hdh

    bn = min(block_n, n)
    bk = min(block_k, din)
    assert n % bn == 0 and din % bk == 0, (n, bn, din, bk)
    grid = (n // bn, din // bk)

    out = pl.pallas_call(
        functools.partial(_kernel, heads=heads, head_dim=head_dim),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, k: (i, k)),
            pl.BlockSpec((bk, hdh), lambda i, k: (k, 0)),
            pl.BlockSpec((1, hdh), lambda i, k: (0, 0)),
            pl.BlockSpec((heads, head_dim), lambda i, k: (0, 0)),
            pl.BlockSpec((heads, head_dim), lambda i, k: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn, hdh), lambda i, k: (i, 0)),
            pl.BlockSpec((bn, heads), lambda i, k: (i, 0)),
            pl.BlockSpec((bn, heads), lambda i, k: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, hdh), x.dtype),
            jax.ShapeDtypeStruct((n, heads), jnp.float32),
            jax.ShapeDtypeStruct((n, heads), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bn, hdh), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="fused_fp_coeff",
    )(x, w, b.reshape(1, -1), a_src, a_dst)
    return tuple(out)
