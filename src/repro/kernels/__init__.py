"""Pallas TPU kernels for the compute hot-spots HiHGNN optimizes:

* seg_gat_agg      — fused NA: block-sparse online-softmax aggregation
                     (the paper's stage-fusion datapath + softmax
                     decomposition, Fig. 6/7)
* fused_fp_coeff   — FP fused with attention-coefficient computation
                     (paper Alg. 2 lines 7-8)
* flash_attention  — the same online-softmax insight on dense attention
                     (LM architectures; windowed for local attention)
* seg_gat_agg_multigraph — the multi-lane execution (§4.2) in one kernel:
                     work units from different semantic graphs dispatched
                     via scalar-prefetched (graph_id, dst_row) tables
* seg_gat_agg_fused_fp — the stage-fusion megakernel (Alg. 2): the
                     multigraph launch with FP pulled inside — raw
                     feature tiles projected on-chip, h' never
                     materialized (DESIGN.md §10)
"""
from . import ops
from .ops import flash_attention, fused_fp_coeff, seg_gat_agg
from .seg_gat_agg_fused_fp import fused_fp_na_reference, seg_gat_agg_fused_fp
from .seg_gat_agg_multigraph import seg_gat_agg_multigraph

__all__ = [
    "ops",
    "flash_attention",
    "fused_fp_coeff",
    "fused_fp_na_reference",
    "seg_gat_agg",
    "seg_gat_agg_fused_fp",
    "seg_gat_agg_multigraph",
]
