"""Multi-graph fused NA kernel — the paper's multi-lane execution (§4.2)
at the Pallas level, forward AND backward.

One kernel launch processes work units from *different* semantic graphs:
each unit is a (graph, dst-block-row) pair, exactly the work unit of
core/multilane.py.  Scalar-prefetched ``graph_id``/``dst_row`` tables
drive the BlockSpec index maps, so the per-unit theta tables (per-graph
attention coefficients — the RAB-cached values) and the shared h_src
stream in without any host-side regrouping: the hardware analogue of the
Local Scheduler dispatching mixed-graph workloads onto one lane.

Grid: (H, U, W) — U work units, W block slots per unit; scratch
(m, l, acc) carries across W (online softmax, Fig. 6).  The forward
additionally emits the per-row log-sum-exp (lse = m + log l), the only
residual the backward needs beyond the inputs.

The backward is itself one fused multigraph launch (the
kernel-consolidation result of arXiv 2408.08490 applied to training):
it *recomputes* the attention probabilities online from lse
(p = exp(logits - lse), flash-attention style — no [U, W, B, B, H]
probability tensor is ever materialized) and produces

  * d_theta_dst  — accumulated across the W axis in VMEM scratch,
    written once per (unit, head);
  * per-(unit, slot) d_theta_src / d_h_src block partials — the GSF-like
    scatter-add onto the shared src vertex space happens outside the
    kernel with segment sums (Pallas TPU cannot safely revisit output
    blocks in non-consecutive grid steps).

``seg_gat_agg_multigraph`` carries a ``jax.custom_vjp``, so HAN training
consolidates all relations of a step into a single forward and a single
backward launch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams

NEG_INF = -1e30


def _fwd_kernel(
    # scalar prefetch
    col_ref,    # int32 [U, W]
    gid_ref,    # int32 [U]
    row_ref,    # int32 [U]
    bias_ref,   # f32   [G, H]
    # inputs
    mask_ref,   # bool [1, 1, B, B]
    thd_ref,    # f32  [1, B, 1]   (graph-indexed dst coefficients)
    ths_ref,    # f32  [1, B, 1]   (graph-indexed src coefficients)
    hs_ref,     # f32  [B, 1, Dh]  (shared source features)
    # outputs
    out_ref,    # [B, 1, Dh]
    lse_ref,    # f32 [B, 1]
    # scratch
    acc_ref, m_ref, l_ref,
    *,
    leaky_slope: float,
):
    h = pl.program_id(0)
    u = pl.program_id(1)
    w = pl.program_id(2)
    nw = pl.num_programs(2)

    @pl.when(w == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    col = col_ref[u, w]
    live = jnp.logical_and(mask_ref[0, 0], col >= 0)
    thd = thd_ref[0, :, 0].astype(jnp.float32)
    ths = ths_ref[0, :, 0].astype(jnp.float32)
    logits = thd[:, None] + ths[None, :] + bias_ref[gid_ref[u], h]
    logits = jnp.where(logits >= 0, logits, leaky_slope * logits)
    logits = jnp.where(live, logits, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1))
    scale = jnp.exp(m_prev - m_new)
    p = jnp.where(live, jnp.exp(logits - m_new[:, None]), 0.0)
    l_ref[...] = l_ref[...] * scale + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * scale[:, None] + jnp.dot(
        p, hs_ref[:, 0, :].astype(jnp.float32), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(w == nw - 1)
    def _finalize():
        l_fin = l_ref[...]
        out_ref[:, 0, :] = (
            acc_ref[...] / jnp.maximum(l_fin, 1e-9)[:, None]
        ).astype(out_ref.dtype)
        # lse of a fully-masked row degenerates to ~NEG_INF; the backward
        # masks those positions with `live` before any use.
        lse_ref[:, 0] = m_ref[...] + jnp.log(jnp.maximum(l_fin, 1e-30))


def _bwd_kernel(
    # scalar prefetch
    col_ref,    # int32 [U, W]
    gid_ref,    # int32 [U]
    row_ref,    # int32 [U]
    bias_ref,   # f32   [G, H]
    # inputs
    mask_ref,   # bool [1, 1, B, B]
    thd_ref,    # [1, B, 1]
    ths_ref,    # [1, B, 1]
    hs_ref,     # [B, 1, Dh]
    gout_ref,   # [B, 1, Dh]  cotangent of the per-unit output
    lse_ref,    # f32 [B, 1]  forward log-sum-exp residual
    delta_ref,  # f32 [B, 1]  sum_f g_out * out (flash-attention delta)
    # outputs
    dths_ref,   # f32 [1, 1, B, 1]      per-(unit, slot) src-coeff partial
    dhs_ref,    # f32 [1, 1, B, 1, Dh]  per-(unit, slot) src-feature partial
    dthd_ref,   # f32 [B, 1]            per-unit dst-coeff gradient
    # scratch
    dthd_acc_ref,  # f32 [B]
    *,
    leaky_slope: float,
):
    h = pl.program_id(0)
    u = pl.program_id(1)
    w = pl.program_id(2)
    nw = pl.num_programs(2)

    @pl.when(w == 0)
    def _init():
        dthd_acc_ref[...] = jnp.zeros_like(dthd_acc_ref)

    col = col_ref[u, w]
    live = jnp.logical_and(mask_ref[0, 0], col >= 0)  # [B(dst), B(src)]
    thd = thd_ref[0, :, 0].astype(jnp.float32)
    ths = ths_ref[0, :, 0].astype(jnp.float32)
    pre = thd[:, None] + ths[None, :] + bias_ref[gid_ref[u], h]
    logits = jnp.where(pre >= 0, pre, leaky_slope * pre)  # LeakyReLU
    # recompute-p: attention probabilities from the lse residual
    p = jnp.where(live, jnp.exp(logits - lse_ref[:, 0][:, None]), 0.0)

    g_out = gout_ref[:, 0, :].astype(jnp.float32)  # [B, Dh]
    hs = hs_ref[:, 0, :].astype(jnp.float32)       # [B, Dh]
    dp = jnp.dot(g_out, hs.T, preferred_element_type=jnp.float32)  # [Bd, Bs]
    dlogit = p * (dp - delta_ref[:, 0][:, None])   # softmax backward
    dpre = jnp.where(pre >= 0, dlogit, leaky_slope * dlogit)

    dths_ref[0, 0, :, 0] = jnp.sum(dpre, axis=0)
    dhs_ref[0, 0, :, 0, :] = jnp.dot(p.T, g_out, preferred_element_type=jnp.float32)
    dthd_acc_ref[...] += jnp.sum(dpre, axis=1)

    @pl.when(w == nw - 1)
    def _finalize():
        dthd_ref[:, 0] = dthd_acc_ref[...]


def _common_maps():
    def mask_map(h, u, w, col, gid, row, bias):
        return (u, w, 0, 0)

    def thd_map(h, u, w, col, gid, row, bias):
        return (gid[u], row[u], h)

    def ths_map(h, u, w, col, gid, row, bias):
        return (gid[u], jnp.maximum(col[u, w], 0), h)

    def hs_map(h, u, w, col, gid, row, bias):
        return (jnp.maximum(col[u, w], 0), h, 0)

    return mask_map, thd_map, ths_map, hs_map


def _fwd_call(col_index, graph_id, dst_row, masks, theta_src, theta_dst,
              h_src, edge_bias, leaky_slope, interpret):
    U, W = col_index.shape
    B = masks.shape[-1]
    G, ns_pad, H = theta_src.shape
    Dh = h_src.shape[-1]
    mask_map, thd_map, ths_map, hs_map = _common_maps()

    def out_map(h, u, w, col, gid, row, bias):
        return (u, h, 0)

    def lse_map(h, u, w, col, gid, row, bias):
        return (u, h)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(H, U, W),
        in_specs=[
            pl.BlockSpec((1, 1, B, B), mask_map),
            pl.BlockSpec((1, B, 1), thd_map),
            pl.BlockSpec((1, B, 1), ths_map),
            pl.BlockSpec((B, 1, Dh), hs_map),
        ],
        out_specs=[
            pl.BlockSpec((B, 1, Dh), out_map),
            pl.BlockSpec((B, 1), lse_map),
        ],
        scratch_shapes=[
            pltpu.VMEM((B, Dh), jnp.float32),
            pltpu.VMEM((B,), jnp.float32),
            pltpu.VMEM((B,), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_fwd_kernel, leaky_slope=leaky_slope),
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((U * B, H, Dh), h_src.dtype),
            jax.ShapeDtypeStruct((U * B, H), jnp.float32),
        ),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
        name="seg_gat_agg_multigraph",
    )(col_index, graph_id, dst_row, edge_bias, masks, theta_dst, theta_src, h_src)


def _bwd_call(col_index, graph_id, dst_row, masks, theta_src, theta_dst,
              h_src, edge_bias, g_out, lse, delta, leaky_slope, interpret):
    U, W = col_index.shape
    B = masks.shape[-1]
    G, ns_pad, H = theta_src.shape
    Dh = h_src.shape[-1]
    mask_map, thd_map, ths_map, hs_map = _common_maps()

    def gout_map(h, u, w, col, gid, row, bias):
        return (u, h, 0)

    def unit_vec_map(h, u, w, col, gid, row, bias):
        return (u, h)

    def dths_map(h, u, w, col, gid, row, bias):
        return (u, w, 0, h)

    def dhs_map(h, u, w, col, gid, row, bias):
        return (u, w, 0, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(H, U, W),
        in_specs=[
            pl.BlockSpec((1, 1, B, B), mask_map),
            pl.BlockSpec((1, B, 1), thd_map),
            pl.BlockSpec((1, B, 1), ths_map),
            pl.BlockSpec((B, 1, Dh), hs_map),
            pl.BlockSpec((B, 1, Dh), gout_map),
            pl.BlockSpec((B, 1), unit_vec_map),
            pl.BlockSpec((B, 1), unit_vec_map),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, B, 1), dths_map),
            pl.BlockSpec((1, 1, B, 1, Dh), dhs_map),
            pl.BlockSpec((B, 1), unit_vec_map),
        ],
        scratch_shapes=[pltpu.VMEM((B,), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_bwd_kernel, leaky_slope=leaky_slope),
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((U, W, B, H), jnp.float32),
            jax.ShapeDtypeStruct((U, W, B, H, Dh), jnp.float32),
            jax.ShapeDtypeStruct((U * B, H), jnp.float32),
        ),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
        name="seg_gat_agg_multigraph_bwd",
    )(col_index, graph_id, dst_row, edge_bias, masks, theta_dst, theta_src,
      h_src, g_out, lse, delta)


@functools.partial(jax.custom_vjp, nondiff_argnums=(8, 9))
def _multigraph(col_index, graph_id, dst_row, masks, theta_src, theta_dst,
                h_src, edge_bias, leaky_slope, interpret):
    out, _ = _fwd_call(col_index, graph_id, dst_row, masks, theta_src,
                       theta_dst, h_src, edge_bias, leaky_slope, interpret)
    return out


def _multigraph_fwd(col_index, graph_id, dst_row, masks, theta_src, theta_dst,
                    h_src, edge_bias, leaky_slope, interpret):
    out, lse = _fwd_call(col_index, graph_id, dst_row, masks, theta_src,
                         theta_dst, h_src, edge_bias, leaky_slope, interpret)
    res = (col_index, graph_id, dst_row, masks, theta_src, theta_dst, h_src,
           edge_bias, out, lse)
    return out, res


def _multigraph_bwd(leaky_slope, interpret, res, g):
    (col_index, graph_id, dst_row, masks, theta_src, theta_dst, h_src,
     edge_bias, out, lse) = res
    U, W = col_index.shape
    B = masks.shape[-1]
    G, ns_pad, H = theta_src.shape
    Dh = h_src.shape[-1]
    nblk = ns_pad // B
    rd = theta_dst.shape[1] // B

    delta = jnp.sum(g.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)
    dths_blk, dhs_blk, dthd_units = _bwd_call(
        col_index, graph_id, dst_row, masks, theta_src, theta_dst, h_src,
        edge_bias, g, lse, delta, leaky_slope, interpret,
    )

    # GSF-like scatter of the per-(unit, slot) partials onto the shared
    # src vertex space.  Padding slots (col < 0) carry exact zeros (p=0),
    # but mask them anyway so their block-0 landing spot stays clean.
    flat_col = col_index.reshape(U * W)
    live_blk = flat_col >= 0
    col_safe = jnp.maximum(flat_col, 0)
    gid_blk = jnp.repeat(graph_id, W)

    dths_blk = jnp.where(live_blk[:, None, None], dths_blk.reshape(U * W, B, H), 0.0)
    d_theta_src = jax.ops.segment_sum(
        dths_blk, gid_blk * nblk + col_safe, num_segments=G * nblk
    ).reshape(G, ns_pad, H)

    dhs_blk = jnp.where(
        live_blk[:, None, None, None], dhs_blk.reshape(U * W, B, H, Dh), 0.0
    )
    d_h_src = jax.ops.segment_sum(
        dhs_blk, col_safe, num_segments=nblk
    ).reshape(ns_pad, H, Dh)

    d_theta_dst = (
        jnp.zeros((G, rd, B, H), jnp.float32)
        .at[graph_id, dst_row]
        .add(dthd_units.reshape(U, B, H))
        .reshape(G, rd * B, H)
    )
    # bias enters every logit additively: its gradient is the total dpre
    # mass per graph, already summed over dst inside dths_blk.
    d_bias = jax.ops.segment_sum(dths_blk.sum(axis=1), gid_blk, num_segments=G)

    f0 = lambda x: np.zeros(x.shape, jax.dtypes.float0)
    return (
        f0(col_index), f0(graph_id), f0(dst_row), f0(masks),
        d_theta_src.astype(theta_src.dtype),
        d_theta_dst.astype(theta_dst.dtype),
        d_h_src.astype(h_src.dtype),
        d_bias.astype(edge_bias.dtype),
    )


_multigraph.defvjp(_multigraph_fwd, _multigraph_bwd)


@functools.partial(jax.jit, static_argnames=("leaky_slope", "interpret"))
def seg_gat_agg_multigraph(
    col_index: jnp.ndarray,  # int32 [U, W]  src block columns (-1 pad, unique/row)
    graph_id: jnp.ndarray,   # int32 [U]
    dst_row: jnp.ndarray,    # int32 [U]     dst block row within the graph
    masks: jnp.ndarray,      # bool  [U, W, B, B]
    theta_src: jnp.ndarray,  # f32   [G, Ns_pad, H]
    theta_dst: jnp.ndarray,  # f32   [G, Nd_pad, H]
    h_src: jnp.ndarray,      # f32   [Ns_pad, H, Dh] (shared across graphs)
    edge_bias: jnp.ndarray | None = None,  # [G, H]
    *,
    leaky_slope: float = 0.2,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns per-unit aggregates [U*B, H, Dh] (caller scatters by
    (graph_id, dst_row) — disjoint by construction).  Differentiable wrt
    theta_src / theta_dst / h_src / edge_bias via a fused Pallas backward."""
    G, _, H = theta_src.shape
    if edge_bias is None:
        edge_bias = jnp.zeros((G, H), jnp.float32)
    edge_bias = jnp.asarray(edge_bias, jnp.float32)
    return _multigraph(
        col_index, graph_id, dst_row, masks, theta_src, theta_dst, h_src,
        edge_bias, float(leaky_slope), bool(interpret),
    )
