"""Multi-graph fused NA kernel — the paper's multi-lane execution (§4.2)
at the Pallas level.

One kernel launch processes work units from *different* semantic graphs:
each unit is a (graph, dst-block-row) pair, exactly the work unit of
core/multilane.py.  Scalar-prefetched ``graph_id``/``dst_row`` tables
drive the BlockSpec index maps, so the per-unit theta tables (per-graph
attention coefficients — the RAB-cached values) and the shared h_src
stream in without any host-side regrouping: the hardware analogue of the
Local Scheduler dispatching mixed-graph workloads onto one lane.

Grid: (H, U, W) — U work units, W block slots per unit; scratch
(m, l, acc) carries across W (online softmax, Fig. 6).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams

NEG_INF = -1e30


def _kernel(
    # scalar prefetch
    col_ref,    # int32 [U, W]
    gid_ref,    # int32 [U]
    row_ref,    # int32 [U]
    bias_ref,   # f32   [G, H]
    # inputs
    mask_ref,   # bool [1, 1, B, B]
    thd_ref,    # f32  [1, B, 1]   (graph-indexed dst coefficients)
    ths_ref,    # f32  [1, B, 1]   (graph-indexed src coefficients)
    hs_ref,     # f32  [B, 1, Dh]  (shared source features)
    # output
    out_ref,    # [B, 1, Dh]
    # scratch
    acc_ref, m_ref, l_ref,
    *,
    leaky_slope: float,
):
    h = pl.program_id(0)
    u = pl.program_id(1)
    w = pl.program_id(2)
    nw = pl.num_programs(2)

    @pl.when(w == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    col = col_ref[u, w]
    live = jnp.logical_and(mask_ref[0, 0], col >= 0)
    thd = thd_ref[0, :, 0]
    ths = ths_ref[0, :, 0]
    logits = thd[:, None] + ths[None, :] + bias_ref[gid_ref[u], h]
    logits = jnp.where(logits >= 0, logits, leaky_slope * logits)
    logits = jnp.where(live, logits, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1))
    scale = jnp.exp(m_prev - m_new)
    p = jnp.where(live, jnp.exp(logits - m_new[:, None]), 0.0)
    l_ref[...] = l_ref[...] * scale + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * scale[:, None] + jnp.dot(
        p, hs_ref[:, 0, :].astype(jnp.float32), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(w == nw - 1)
    def _finalize():
        out_ref[:, 0, :] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-9)[:, None]
        ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("leaky_slope", "interpret"))
def seg_gat_agg_multigraph(
    col_index: jnp.ndarray,  # int32 [U, W]  src block columns (-1 pad, unique/row)
    graph_id: jnp.ndarray,   # int32 [U]
    dst_row: jnp.ndarray,    # int32 [U]     dst block row within the graph
    masks: jnp.ndarray,      # bool  [U, W, B, B]
    theta_src: jnp.ndarray,  # f32   [G, Ns_pad, H]
    theta_dst: jnp.ndarray,  # f32   [G, Nd_pad, H]
    h_src: jnp.ndarray,      # f32   [Ns_pad, H, Dh] (shared across graphs)
    edge_bias: jnp.ndarray | None = None,  # [G, H]
    *,
    leaky_slope: float = 0.2,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns per-unit aggregates [U*B, H, Dh] (caller scatters by
    (graph_id, dst_row) — disjoint by construction)."""
    U, W = col_index.shape
    B = masks.shape[-1]
    G, ns_pad, H = theta_src.shape
    Dh = h_src.shape[-1]
    if edge_bias is None:
        edge_bias = jnp.zeros((G, H), jnp.float32)

    grid = (H, U, W)

    def mask_map(h, u, w, col, gid, row, bias):
        return (u, w, 0, 0)

    def thd_map(h, u, w, col, gid, row, bias):
        return (gid[u], row[u], h)

    def ths_map(h, u, w, col, gid, row, bias):
        return (gid[u], jnp.maximum(col[u, w], 0), h)

    def hs_map(h, u, w, col, gid, row, bias):
        return (jnp.maximum(col[u, w], 0), h, 0)

    def out_map(h, u, w, col, gid, row, bias):
        return (u, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, B, B), mask_map),
            pl.BlockSpec((1, B, 1), thd_map),
            pl.BlockSpec((1, B, 1), ths_map),
            pl.BlockSpec((B, 1, Dh), hs_map),
        ],
        out_specs=pl.BlockSpec((B, 1, Dh), out_map),
        scratch_shapes=[
            pltpu.VMEM((B, Dh), jnp.float32),
            pltpu.VMEM((B,), jnp.float32),
            pltpu.VMEM((B,), jnp.float32),
        ],
    )
    # theta tables are [G, N, H] with block (1, B, 1): graph-indexed rows
    thd_blocked = theta_dst
    ths_blocked = theta_src
    return pl.pallas_call(
        functools.partial(_kernel, leaky_slope=leaky_slope),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((U * B, H, Dh), h_src.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
        name="seg_gat_agg_multigraph",
    )(col_index, graph_id, dst_row, edge_bias, masks, thd_blocked, ths_blocked, h_src)
