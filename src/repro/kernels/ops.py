"""Public jit'd entry points for the Pallas kernels.

Every op takes ``interpret=`` so the TPU kernel body can be validated on
CPU (interpret mode executes the kernel in Python).  ``ref``-suffixed
oracles live in ref.py; tests sweep shapes/dtypes and assert_allclose.
"""
from __future__ import annotations

from .flash_attention import flash_attention
from .fused_fp_coeff import fused_fp_coeff
from .ref import ref_flash_attention, ref_fused_fp_coeff, ref_seg_gat_agg
from .seg_gat_agg import seg_gat_agg

__all__ = [
    "flash_attention",
    "fused_fp_coeff",
    "seg_gat_agg",
    "ref_flash_attention",
    "ref_fused_fp_coeff",
    "ref_seg_gat_agg",
]
