"""Stage-fusion megakernel: fused FP+NA forward and backward (Pallas TPU).

Paper Alg. 2 bound-aware stage fusion, executed instead of modeled: the
kernel streams **raw** source-feature tiles from HBM, projects them
on-chip against a scalar-prefetched per-graph weight table (``W[g]`` via
the ``wsel`` graph->table map), contracts the projected tile with
a_src/a_dst into attention coefficients while it is VMEM-resident (the
``fused_fp_coeff`` tile-matmul pattern), and feeds it straight into the
online-softmax aggregation of ``seg_gat_agg_multigraph`` — projected
features never round-trip through HBM.

Work units are the multigraph kernel's (graph, dst-block-row) pairs,
grid (U, W) with W the sequential block-slot sweep.  While unit/slot
(u, w) runs its projection matmul on the MXU, the Pallas grid/BlockSpec
pipeline is already fetching slot (u, w+1)'s raw-feature tile (and, at a
unit boundary, the next graph's weight table) from HBM — compute-bound FP
of the current tile overlapped with the memory-bound feature fetch of the
next, which is exactly the paper's FP/NA overlap (DESIGN.md §10).  The
dst tile of a unit is projected once at w == 0 and its theta_dst kept in
VMEM scratch for the whole sweep.

The backward is one fused launch too: it *recomputes* the projection
(flash-attention style recompute-p from the lse residual, extended one
stage earlier to the FP matmul) and emits

  * per-(unit, slot) projection-space src gradients ``dhs`` and per-unit
    dst gradients ``dhd`` — the chain into dW[g]/db[g]/dx happens
    *outside* the kernel via per-weight-table segment sums + two einsums.
    The ISSUE sketch accumulates dW[g] in VMEM scratch across the
    sequential axis; that is only safe when all units of a table are
    contiguous in the grid, which the multilane plan does not guarantee
    (lanes interleave graphs), and Pallas TPU cannot revisit an output
    block in non-consecutive grid steps.  The segment-sum scatter is the
    same trick the multigraph backward already uses for d_theta_src.
  * per-unit d_theta_dst (VMEM-scratch accumulated over W) and per-unit
    d_a_src / d_a_dst partials, scattered per graph outside.

``seg_gat_agg_fused_fp`` carries a ``jax.custom_vjp``; HAN training with
``NABackend.FUSED_FP`` runs one forward and one backward launch per layer
with no materialized h'.

The weight table rides in whole (``Din`` untiled): one (Din, H*Dh) block
per table.  For the repo's HGNN widths (Din up to a few thousand) that is
well inside VMEM; K-tiling the projection would force the softmax state
machine to nest under a reduction axis for no measured benefit yet.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams

NEG_INF = -1e30


def _fwd_kernel(
    # scalar prefetch
    col_ref,    # int32 [U, W]
    gid_ref,    # int32 [U]
    row_ref,    # int32 [U]
    wsel_ref,   # int32 [G]   graph -> weight-table row
    bias_ref,   # f32   [G, H]
    # inputs
    mask_ref,   # bool [1, 1, B, B]
    xd_ref,     # [B, Din]      raw dst tile (row_ref-indexed)
    xs_ref,     # [B, Din]      raw src tile (col-indexed)
    w_ref,      # [1, Din, HDh] weight table of the unit's graph
    b_ref,      # [1, HDh]
    asrc_ref,   # [1, H, Dh]
    adst_ref,   # [1, H, Dh]
    # outputs
    out_ref,    # [B, HDh]
    lse_ref,    # f32 [B, H]
    # scratch
    acc_ref,    # f32 [B, HDh]
    m_ref,      # f32 [B, H]
    l_ref,      # f32 [B, H]
    thd_ref,    # f32 [B, H]   dst coefficients, computed once per unit
    *,
    heads: int,
    head_dim: int,
    leaky_slope: float,
):
    u = pl.program_id(0)
    w = pl.program_id(1)
    nw = pl.num_programs(1)

    wmat = w_ref[0].astype(jnp.float32)  # [Din, HDh]
    bvec = b_ref[0].astype(jnp.float32)  # [HDh]

    @pl.when(w == 0)
    def _init():
        # FP of the unit's dst tile, once per unit — theta_dst stays
        # VMEM-resident for the whole W sweep (amortized over the slots).
        hd = jnp.dot(
            xd_ref[...].astype(jnp.float32), wmat,
            preferred_element_type=jnp.float32,
        ) + bvec
        for hh in range(heads):
            seg = hd[:, hh * head_dim : (hh + 1) * head_dim]
            thd_ref[:, hh] = jnp.dot(
                seg, adst_ref[0, hh].astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    col = col_ref[u, w]
    live = jnp.logical_and(mask_ref[0, 0], col >= 0)
    # FP of the current src tile — on-chip, straight off the raw fetch
    hs = jnp.dot(
        xs_ref[...].astype(jnp.float32), wmat,
        preferred_element_type=jnp.float32,
    ) + bvec  # [B, HDh]
    for hh in range(heads):
        sl = slice(hh * head_dim, (hh + 1) * head_dim)
        seg = hs[:, sl]
        ths = jnp.dot(
            seg, asrc_ref[0, hh].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )  # [B]
        pre = thd_ref[:, hh][:, None] + ths[None, :] + bias_ref[gid_ref[u], hh]
        logits = jnp.where(pre >= 0, pre, leaky_slope * pre)
        logits = jnp.where(live, logits, NEG_INF)
        m_prev = m_ref[:, hh]
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1))
        scale = jnp.exp(m_prev - m_new)
        p = jnp.where(live, jnp.exp(logits - m_new[:, None]), 0.0)
        l_ref[:, hh] = l_ref[:, hh] * scale + jnp.sum(p, axis=1)
        acc_ref[:, sl] = acc_ref[:, sl] * scale[:, None] + jnp.dot(
            p, seg, preferred_element_type=jnp.float32
        )
        m_ref[:, hh] = m_new

    @pl.when(w == nw - 1)
    def _finalize():
        for hh in range(heads):
            sl = slice(hh * head_dim, (hh + 1) * head_dim)
            out_ref[:, sl] = (
                acc_ref[:, sl]
                / jnp.maximum(l_ref[:, hh], 1e-9)[:, None]
            ).astype(out_ref.dtype)
        # lse of a fully-masked row degenerates to ~NEG_INF; the backward
        # masks those positions with `live` before any use.
        lse_ref[...] = m_ref[...] + jnp.log(jnp.maximum(l_ref[...], 1e-30))


def _bwd_kernel(
    # scalar prefetch
    col_ref, gid_ref, row_ref, wsel_ref, bias_ref,
    # inputs (forward operands + residuals)
    mask_ref, xd_ref, xs_ref, w_ref, b_ref, asrc_ref, adst_ref,
    gout_ref,   # [B, HDh]  cotangent of the per-unit output
    lse_ref,    # f32 [B, H]
    delta_ref,  # f32 [B, H]  sum_f g_out * out (flash-attention delta)
    # outputs
    dhs_ref,    # f32 [1, 1, B, HDh]  per-(unit, slot) src projection grad
    dhd_ref,    # f32 [1, B, HDh]     per-unit dst projection grad
    dthd_ref,   # f32 [B, H]          per-unit dst-coeff gradient
    das_ref,    # f32 [1, H, Dh]      per-unit d a_src partial
    dad_ref,    # f32 [1, H, Dh]      per-unit d a_dst partial
    # scratch
    thd_scr,    # f32 [B, H]
    hd_scr,     # f32 [B, HDh]  recomputed dst projection (kept for da_dst)
    dthd_acc,   # f32 [B, H]
    das_acc,    # f32 [H, Dh]
    *,
    heads: int,
    head_dim: int,
    leaky_slope: float,
):
    u = pl.program_id(0)
    w = pl.program_id(1)
    nw = pl.num_programs(1)

    wmat = w_ref[0].astype(jnp.float32)
    bvec = b_ref[0].astype(jnp.float32)

    @pl.when(w == 0)
    def _init():
        hd = jnp.dot(
            xd_ref[...].astype(jnp.float32), wmat,
            preferred_element_type=jnp.float32,
        ) + bvec
        hd_scr[...] = hd
        for hh in range(heads):
            seg = hd[:, hh * head_dim : (hh + 1) * head_dim]
            thd_scr[:, hh] = jnp.dot(
                seg, adst_ref[0, hh].astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )
        dthd_acc[...] = jnp.zeros_like(dthd_acc)
        das_acc[...] = jnp.zeros_like(das_acc)

    col = col_ref[u, w]
    live = jnp.logical_and(mask_ref[0, 0], col >= 0)  # [B(dst), B(src)]
    # recompute the src projection (the FP stage) and, from lse, the
    # attention probabilities — nothing was materialized in the forward
    hs = jnp.dot(
        xs_ref[...].astype(jnp.float32), wmat,
        preferred_element_type=jnp.float32,
    ) + bvec
    g_out = gout_ref[...].astype(jnp.float32)  # [B, HDh]
    for hh in range(heads):
        sl = slice(hh * head_dim, (hh + 1) * head_dim)
        seg = hs[:, sl]  # [Bs, Dh]
        ths = jnp.dot(
            seg, asrc_ref[0, hh].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        pre = thd_scr[:, hh][:, None] + ths[None, :] + bias_ref[gid_ref[u], hh]
        logits = jnp.where(pre >= 0, pre, leaky_slope * pre)
        p = jnp.where(live, jnp.exp(logits - lse_ref[:, hh][:, None]), 0.0)
        gseg = g_out[:, sl]  # [Bd, Dh]
        dp = jnp.dot(gseg, seg.T, preferred_element_type=jnp.float32)  # [Bd, Bs]
        dlogit = p * (dp - delta_ref[:, hh][:, None])  # softmax backward
        dpre = jnp.where(pre >= 0, dlogit, leaky_slope * dlogit)
        dths_vec = jnp.sum(dpre, axis=0)  # [Bs]
        dthd_acc[:, hh] += jnp.sum(dpre, axis=1)
        # src projection grad: aggregation term + coefficient term
        dhs_ref[0, 0, :, sl] = jnp.dot(
            p.T, gseg, preferred_element_type=jnp.float32
        ) + dths_vec[:, None] * asrc_ref[0, hh].astype(jnp.float32)[None, :]
        das_acc[hh, :] += jnp.dot(
            dths_vec[None, :], seg, preferred_element_type=jnp.float32
        )[0]

    @pl.when(w == nw - 1)
    def _finalize():
        dthd_ref[...] = dthd_acc[...]
        das_ref[0] = das_acc[...]
        hd = hd_scr[...]
        for hh in range(heads):
            sl = slice(hh * head_dim, (hh + 1) * head_dim)
            dad_ref[0, hh, :] = jnp.dot(
                dthd_acc[:, hh][None, :], hd[:, sl],
                preferred_element_type=jnp.float32,
            )[0]
            # dst projection grad: theta_dst is hd @ a_dst, so d hd is rank-1
            dhd_ref[0, :, sl] = (
                dthd_acc[:, hh][:, None]
                * adst_ref[0, hh].astype(jnp.float32)[None, :]
            )


def _common_maps():
    def mask_map(u, w, col, gid, row, wsel, bias):
        return (u, w, 0, 0)

    def xd_map(u, w, col, gid, row, wsel, bias):
        return (row[u], 0)

    def xs_map(u, w, col, gid, row, wsel, bias):
        return (jnp.maximum(col[u, w], 0), 0)

    def w_map(u, w, col, gid, row, wsel, bias):
        return (wsel[gid[u]], 0, 0)

    def b_map(u, w, col, gid, row, wsel, bias):
        return (wsel[gid[u]], 0)

    def a_map(u, w, col, gid, row, wsel, bias):
        return (gid[u], 0, 0)

    return mask_map, xd_map, xs_map, w_map, b_map, a_map


def _in_specs(B, din, hdh, heads, head_dim):
    mask_map, xd_map, xs_map, w_map, b_map, a_map = _common_maps()
    return [
        pl.BlockSpec((1, 1, B, B), mask_map),
        pl.BlockSpec((B, din), xd_map),
        pl.BlockSpec((B, din), xs_map),
        pl.BlockSpec((1, din, hdh), w_map),
        pl.BlockSpec((1, hdh), b_map),
        pl.BlockSpec((1, heads, head_dim), a_map),
        pl.BlockSpec((1, heads, head_dim), a_map),
    ]


def _fwd_call(col_index, graph_id, dst_row, wsel, masks, x, w, b,
              a_src, a_dst, edge_bias, leaky_slope, interpret):
    U, W = col_index.shape
    B = masks.shape[-1]
    G, heads, head_dim = a_src.shape
    din = x.shape[-1]
    hdh = heads * head_dim

    def out_map(u, w_, col, gid, row, wsel_, bias):
        return (u, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(U, W),
        in_specs=_in_specs(B, din, hdh, heads, head_dim),
        out_specs=[
            pl.BlockSpec((B, hdh), out_map),
            pl.BlockSpec((B, heads), out_map),
        ],
        scratch_shapes=[
            pltpu.VMEM((B, hdh), jnp.float32),
            pltpu.VMEM((B, heads), jnp.float32),
            pltpu.VMEM((B, heads), jnp.float32),
            pltpu.VMEM((B, heads), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(
            _fwd_kernel, heads=heads, head_dim=head_dim, leaky_slope=leaky_slope
        ),
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((U * B, hdh), x.dtype),
            jax.ShapeDtypeStruct((U * B, heads), jnp.float32),
        ),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
        interpret=interpret,
        name="seg_gat_agg_fused_fp",
    )(col_index, graph_id, dst_row, wsel, edge_bias, masks, x, x, w, b, a_src, a_dst)


def _bwd_call(col_index, graph_id, dst_row, wsel, masks, x, w, b, a_src,
              a_dst, edge_bias, g_out, lse, delta, leaky_slope, interpret):
    U, W = col_index.shape
    B = masks.shape[-1]
    G, heads, head_dim = a_src.shape
    din = x.shape[-1]
    hdh = heads * head_dim

    def unit_map(u, w_, col, gid, row, wsel_, bias):
        return (u, 0)

    def dhs_map(u, w_, col, gid, row, wsel_, bias):
        return (u, w_, 0, 0)

    def unit3_map(u, w_, col, gid, row, wsel_, bias):
        return (u, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(U, W),
        in_specs=_in_specs(B, din, hdh, heads, head_dim) + [
            pl.BlockSpec((B, hdh), unit_map),
            pl.BlockSpec((B, heads), unit_map),
            pl.BlockSpec((B, heads), unit_map),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, B, hdh), dhs_map),
            pl.BlockSpec((1, B, hdh), unit3_map),
            pl.BlockSpec((B, heads), unit_map),
            pl.BlockSpec((1, heads, head_dim), unit3_map),
            pl.BlockSpec((1, heads, head_dim), unit3_map),
        ],
        scratch_shapes=[
            pltpu.VMEM((B, heads), jnp.float32),
            pltpu.VMEM((B, hdh), jnp.float32),
            pltpu.VMEM((B, heads), jnp.float32),
            pltpu.VMEM((heads, head_dim), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(
            _bwd_kernel, heads=heads, head_dim=head_dim, leaky_slope=leaky_slope
        ),
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((U, W, B, hdh), jnp.float32),
            jax.ShapeDtypeStruct((U, B, hdh), jnp.float32),
            jax.ShapeDtypeStruct((U * B, heads), jnp.float32),
            jax.ShapeDtypeStruct((U, heads, head_dim), jnp.float32),
            jax.ShapeDtypeStruct((U, heads, head_dim), jnp.float32),
        ),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
        interpret=interpret,
        name="seg_gat_agg_fused_fp_bwd",
    )(col_index, graph_id, dst_row, wsel, edge_bias, masks, x, x, w, b,
      a_src, a_dst, g_out, lse, delta)


@functools.partial(jax.custom_vjp, nondiff_argnums=(11, 12))
def _fused(col_index, graph_id, dst_row, wsel, masks, x, w, b, a_src,
           a_dst, edge_bias, leaky_slope, interpret):
    out, _ = _fwd_call(col_index, graph_id, dst_row, wsel, masks, x, w, b,
                       a_src, a_dst, edge_bias, leaky_slope, interpret)
    U = col_index.shape[0]
    B = masks.shape[-1]
    heads, head_dim = a_src.shape[1:]
    return out.reshape(U * B, heads, head_dim)


def _fused_fwd(col_index, graph_id, dst_row, wsel, masks, x, w, b, a_src,
               a_dst, edge_bias, leaky_slope, interpret):
    out_flat, lse = _fwd_call(col_index, graph_id, dst_row, wsel, masks, x,
                              w, b, a_src, a_dst, edge_bias, leaky_slope,
                              interpret)
    U = col_index.shape[0]
    B = masks.shape[-1]
    heads, head_dim = a_src.shape[1:]
    out = out_flat.reshape(U * B, heads, head_dim)
    res = (col_index, graph_id, dst_row, wsel, masks, x, w, b, a_src, a_dst,
           edge_bias, out, lse)
    return out, res


def _fused_bwd(leaky_slope, interpret, res, g):
    (col_index, graph_id, dst_row, wsel, masks, x, w, b, a_src, a_dst,
     edge_bias, out, lse) = res
    U, W = col_index.shape
    B = masks.shape[-1]
    G, heads, head_dim = a_src.shape
    T = w.shape[0]
    n_pad = x.shape[0]
    hdh = heads * head_dim
    nblk = n_pad // B

    delta = jnp.sum(g.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)
    g_flat = g.reshape(U * B, hdh)
    dhs_blk, dhd_units, dthd_units, das_units, dad_units = _bwd_call(
        col_index, graph_id, dst_row, wsel, masks, x, w, b, a_src, a_dst,
        edge_bias, g_flat, lse, delta, leaky_slope, interpret,
    )

    # Scatter the projection-space gradients onto the shared vertex space,
    # segmented per weight table: src-side per-slot partials and dst-side
    # per-unit partials share one segment sum.  Padding slots (col < 0)
    # carry exact zeros (p = 0), but mask them anyway so their block-0
    # landing spot stays clean.
    flat_col = col_index.reshape(U * W)
    live_blk = flat_col >= 0
    col_safe = jnp.maximum(flat_col, 0)
    gid_blk = jnp.repeat(graph_id, W)
    dhs_blk = jnp.where(
        live_blk[:, None, None], dhs_blk.reshape(U * W, B, hdh), 0.0
    )
    keys = jnp.concatenate([
        wsel[gid_blk] * nblk + col_safe,
        wsel[graph_id] * nblk + dst_row,
    ])
    vals = jnp.concatenate([dhs_blk, dhd_units], axis=0)
    dh_t = jax.ops.segment_sum(
        vals, keys, num_segments=T * nblk
    ).reshape(T, n_pad, hdh)

    # chain h = x @ W[t] + b[t] outside the kernel (see module docstring)
    xf = x.astype(jnp.float32)
    d_w = jnp.einsum("nd,tnk->tdk", xf, dh_t)
    d_b = dh_t.sum(axis=1)
    d_x = jnp.einsum("tnk,tdk->nd", dh_t, w.astype(jnp.float32))
    d_a_src = jax.ops.segment_sum(das_units, graph_id, num_segments=G)
    d_a_dst = jax.ops.segment_sum(dad_units, graph_id, num_segments=G)
    # bias enters every logit additively: its gradient is the total dpre
    # mass per graph, already summed over src inside dthd.
    d_bias = jax.ops.segment_sum(
        dthd_units.reshape(U, B, heads).sum(axis=1), graph_id, num_segments=G
    )

    f0 = lambda a: np.zeros(a.shape, jax.dtypes.float0)
    return (
        f0(col_index), f0(graph_id), f0(dst_row), f0(wsel), f0(masks),
        d_x.astype(x.dtype),
        d_w.astype(w.dtype),
        d_b.astype(b.dtype),
        d_a_src.astype(a_src.dtype),
        d_a_dst.astype(a_dst.dtype),
        d_bias.astype(edge_bias.dtype),
    )


_fused.defvjp(_fused_fwd, _fused_bwd)


@functools.partial(jax.jit, static_argnames=("leaky_slope", "interpret"))
def seg_gat_agg_fused_fp(
    col_index: jnp.ndarray,  # int32 [U, W]  src block columns (-1 pad, unique/row)
    graph_id: jnp.ndarray,   # int32 [U]
    dst_row: jnp.ndarray,    # int32 [U]     dst block row within the graph
    wsel: jnp.ndarray,       # int32 [G]     graph -> weight-table row
    masks: jnp.ndarray,      # bool  [U, W, B, B]
    x: jnp.ndarray,          # [N_pad, Din]  raw features, shared src/dst space
    w: jnp.ndarray,          # [T, Din, H*Dh] (or [Din, H*Dh] shared)
    b: jnp.ndarray,          # [T, H*Dh]      (or [H*Dh] shared)
    a_src: jnp.ndarray,      # [G, H, Dh]
    a_dst: jnp.ndarray,      # [G, H, Dh]
    edge_bias: jnp.ndarray | None = None,  # [G, H]
    *,
    leaky_slope: float = 0.2,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused FP+NA: returns per-unit aggregates [U*B, H, Dh] (same contract
    as ``seg_gat_agg_multigraph`` — caller scatters by (graph_id, dst_row)).
    ``x`` must cover every block index in ``col_index``/``dst_row``
    (N_pad = n_blocks * B; src and dst share the vertex space).
    Differentiable wrt x / w / b / a_src / a_dst / edge_bias via a fused
    Pallas backward that recomputes the projection."""
    G, heads, _ = a_src.shape
    if w.ndim == 2:
        w = w[None]
    if b.ndim == 1:
        b = b[None]
    if edge_bias is None:
        edge_bias = jnp.zeros((G, heads), jnp.float32)
    edge_bias = jnp.asarray(edge_bias, jnp.float32)
    return _fused(
        col_index, graph_id, dst_row, jnp.asarray(wsel, jnp.int32), masks,
        x, w, b, a_src, a_dst, edge_bias, float(leaky_slope), bool(interpret),
    )


def fused_fp_na_reference(
    col_index, graph_id, dst_row, wsel, masks, x, w, b, a_src, a_dst,
    edge_bias=None, *, leaky_slope: float = 0.2,
) -> jnp.ndarray:
    """Pure-jnp oracle for the fused kernel (materialize-then-NA, exact
    softmax).  Differentiable by plain autodiff — the gradcheck target —
    and the CPU fallback path when Pallas is unavailable."""
    U, W = col_index.shape
    B = masks.shape[-1]
    G, heads, head_dim = a_src.shape
    if w.ndim == 2:
        w = w[None]
    if b.ndim == 1:
        b = b[None]
    if edge_bias is None:
        edge_bias = jnp.zeros((G, heads), jnp.float32)
    edge_bias = jnp.asarray(edge_bias, jnp.float32)
    n = x.shape[0]
    h_all = jnp.einsum(
        "nd,tdk->tnk", x.astype(jnp.float32), w.astype(jnp.float32)
    ) + b.astype(jnp.float32)[:, None, :]
    hg = h_all[wsel].reshape(G, n, heads, head_dim)  # per-graph projections
    ths = jnp.einsum("gnhd,ghd->gnh", hg, a_src.astype(jnp.float32))
    thd = jnp.einsum("gnhd,ghd->gnh", hg, a_dst.astype(jnp.float32))

    def one(cols, mrow, gi, r):
        td = jax.lax.dynamic_slice(thd, (gi, r * B, 0), (1, B, heads))[0]
        c_safe = jnp.maximum(cols, 0)
        idx = (c_safe[:, None] * B + jnp.arange(B)[None, :]).reshape(-1)
        ts = ths[gi][idx]   # [W*B, H]
        hs = hg[gi][idx]    # [W*B, H, Dh]
        live = (
            mrow.transpose(1, 0, 2).reshape(B, W * B)
            & jnp.repeat(cols >= 0, B)[None, :]
        )
        pre = td[:, None, :] + ts[None, :, :] + edge_bias[gi][None, None, :]
        logits = jnp.where(pre >= 0, pre, leaky_slope * pre)
        logits = jnp.where(live[:, :, None], logits, NEG_INF)
        m = jnp.max(logits, axis=1, keepdims=True)
        p = jnp.where(live[:, :, None], jnp.exp(logits - m), 0.0)
        agg = jnp.einsum("bsh,shf->bhf", p, hs)
        return agg / jnp.maximum(p.sum(axis=1), 1e-9)[:, :, None]

    out = jax.vmap(one)(col_index, masks, graph_id, dst_row)  # [U, B, H, Dh]
    return out.reshape(U * B, heads, head_dim).astype(x.dtype)
