"""Architecture & shape registry for the assigned pool.

``get_config(arch_id)`` returns the full published config;
``smoke_config(arch_id)`` a drastically reduced same-family variant for
CPU smoke tests.  SHAPES carries the four assigned input shapes; cell
applicability (decode/long-context) is computed here so the dry-run,
tests and EXPERIMENTS.md all agree on the 40-cell grid.
"""
from __future__ import annotations

import dataclasses
import importlib

from ..models.lm.config import LMConfig

ARCH_IDS = [
    "qwen2-vl-7b",
    "llama3.2-3b",
    "qwen2-7b",
    "qwen3-8b",
    "minitron-4b",
    "mamba2-2.7b",
    "whisper-large-v3",
    "recurrentgemma-9b",
    "dbrx-132b",
    "grok-1-314b",
]

_MODULES = {
    "qwen2-vl-7b": "qwen2_vl_7b",
    "llama3.2-3b": "llama3_2_3b",
    "qwen2-7b": "qwen2_7b",
    "qwen3-8b": "qwen3_8b",
    "minitron-4b": "minitron_4b",
    "mamba2-2.7b": "mamba2_2_7b",
    "whisper-large-v3": "whisper_large_v3",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "dbrx-132b": "dbrx_132b",
    "grok-1-314b": "grok_1_314b",
}


def get_config(arch_id: str) -> LMConfig:
    mod = importlib.import_module(f".{_MODULES[arch_id]}", __package__)
    return mod.CONFIG


def smoke_config(arch_id: str) -> LMConfig:
    """Reduced same-family config: small layers/width/experts/vocab."""
    cfg = get_config(arch_id)
    period = len(cfg.block_pattern)
    overrides = dict(
        num_layers=max(2, period + min(1, cfg.num_layers % period)),
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) or 1,
        head_dim=16,
        d_ff=96 if cfg.d_ff else 0,
        vocab_size=257,
        dtype="float32",
        param_dtype="float32",
        fsdp=False,
        remat="none",
    )
    if cfg.is_moe:
        overrides.update(num_experts=4, experts_per_tok=2)
    if cfg.family == "ssm":
        overrides.update(ssm_state=16, ssm_head_dim=8, ssm_chunk=8, num_heads=1, num_kv_heads=1)
    if cfg.rnn_width:
        overrides.update(rnn_width=64)
    if cfg.window:
        overrides.update(window=8)
    if cfg.is_encoder_decoder:
        overrides.update(encoder_layers=2, encoder_seq=16)
    if cfg.m_rope:
        overrides.update(head_dim=16, m_rope_sections=(2, 3, 3))
    return dataclasses.replace(cfg, **overrides)


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}


def cell_supported(cfg: LMConfig, shape: Shape) -> tuple[bool, str]:
    """Is (arch × shape) runnable?  Returns (ok, reason-if-not)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "full-attention arch: 500k dense KV decode is quadratic-in-context (DESIGN.md §5)"
    return True, ""


def grid():
    """All 40 (arch, shape) cells with support flags."""
    out = []
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s in SHAPES.values():
            ok, why = cell_supported(cfg, s)
            out.append((a, s.name, ok, why))
    return out
