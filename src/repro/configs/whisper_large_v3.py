"""whisper-large-v3 [audio] — enc-dec, conv frontend stubbed
[arXiv:2212.04356; unverified].  32 encoder + 32 decoder layers, MHA
(kv == heads == 20), GELU MLP, 1500 encoder frame positions."""
from ..models.lm.config import LMConfig

CONFIG = LMConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,
    encoder_layers=32,
    encoder_seq=1500,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    mlp_gated=False,
    act="gelu",
    tie_embeddings=True,
    fsdp=True,
    remat="full",
    frontend="audio",
)
