"""minitron-4b [dense] — pruned nemotron [arXiv:2407.14679; hf].

Nemotron uses squared-ReLU non-gated MLP; reproduced via act="relu2"."""
from ..models.lm.config import LMConfig

CONFIG = LMConfig(
    name="minitron-4b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab_size=256000,
    rope_theta=1e4,
    mlp_gated=False,
    act="relu2",
    tie_embeddings=False,
    fsdp=True,
    remat="full",
)
