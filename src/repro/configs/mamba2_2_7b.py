"""mamba2-2.7b [ssm] — SSD state-space duality [arXiv:2405.21060; unverified].

Attention-free: 64 mamba2 blocks, d_inner = 2*d_model = 5120, 80 SSD heads
of dim 64, state N=128.  Sub-quadratic: runs long_500k decode (O(1) state)."""
from ..models.lm.config import LMConfig

CONFIG = LMConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=1,
    num_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    block_pattern=("ssm",),
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=128,
    tie_embeddings=True,
    fsdp=True,
    remat="full",
    subquadratic=True,
)
