"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1:2 pattern
[arXiv:2402.19427; unverified].  38 layers = 12×(rglru,rglru,local)+2,
MQA local attention (window 2048), GeGLU MLP, embeddings scaled by
sqrt(d).  Sub-quadratic: long_500k decode state is O(window)."""
from ..models.lm.config import LMConfig

CONFIG = LMConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    block_pattern=("rglru", "rglru", "local"),
    window=2048,
    rnn_width=4096,
    act="gelu",
    rope_theta=1e4,
    embed_scale=True,
    tie_embeddings=True,
    fsdp=True,
    remat="full",
    subquadratic=True,
)
