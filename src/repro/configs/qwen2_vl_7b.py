"""qwen2-vl-7b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

Backbone only per the brief: the vision frontend is a stub; input_specs
provides precomputed patch embeddings merged into the leading slots."""
from ..models.lm.config import LMConfig

CONFIG = LMConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    qkv_bias=True,
    m_rope=True,
    m_rope_sections=(16, 24, 24),
    rope_theta=1e6,
    tie_embeddings=False,
    fsdp=True,
    remat="full",
    param_dtype="bfloat16",
    frontend="vision",
)
