"""qwen3-8b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf]."""
from ..models.lm.config import LMConfig

CONFIG = LMConfig(
    name="qwen3-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=12288,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1e6,
    tie_embeddings=False,
    fsdp=True,
    remat="full",
    param_dtype="bfloat16",
)
