"""grok-1-314b [moe] — 8 experts top-2 [hf:xai-org/grok-1; unverified].

8 experts do not divide the 16-way model axis; experts are replicated and
each expert's FFN is tensor-parallel over `model` while parameters are
additionally FSDP-sharded over `data` (DESIGN.md §5).  bf16 params +
sharded optimizer state to fit 16 GB/chip (DESIGN.md §7)."""
from ..models.lm.config import LMConfig

CONFIG = LMConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    num_experts=8,
    experts_per_tok=2,
    ep_shard=False,
    logits_soft_cap=30.0,
    rope_theta=1e4,
    tie_embeddings=True,
    fsdp=True,
    remat="full",
    param_dtype="bfloat16",
)
