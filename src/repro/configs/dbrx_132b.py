"""dbrx-132b [moe] — 16 experts top-4, fine-grained
[hf:databricks/dbrx-base; unverified].  Experts shard exactly onto the
16-way model axis: full expert parallelism (DESIGN.md §5 — the HiHGNN
multi-lane analogue)."""
from ..models.lm.config import LMConfig

CONFIG = LMConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    num_experts=16,
    experts_per_tok=4,
    rope_theta=5e5,
    tie_embeddings=False,
    fsdp=True,
    remat="full",
    param_dtype="bfloat16",
)
