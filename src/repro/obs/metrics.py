"""Process-wide metrics: counters, gauges, log-bucketed histograms.

The second observability pillar (DESIGN.md §12): where ``obs.trace``
answers *when* each stage ran, this registry answers *how much* — NA
launches, FP rows computed vs reused, per-step latency distributions,
predicted-vs-measured drift gauges.  Series are labeled, so one process
can hold e.g. ``serve.step_ms{admission=similarity}`` next to the FIFO
ablation, and a JSON snapshot is the scrape format the CI workflow
uploads next to the benchmark baselines.

Histograms are log-bucketed: observation ``v`` lands in the bucket with
upper edge ``base**k`` for the smallest integer ``k`` with
``base**k >= v`` (non-positive values go to a dedicated underflow
bucket).  Log buckets hold latency spreads spanning 4+ decades — a
compile-step outlier and a steady-state step coexist without choosing
edges up front — and quantiles come back as bucket upper edges, i.e.
conservative (never under-reported).
"""
from __future__ import annotations

import json
import math
import threading

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "get_registry",
    "reset_registry",
]


class Counter:
    """Monotonically increasing count of events."""

    __slots__ = ("value",)
    kind = "counter"

    def __init__(self):
        self.value = 0

    def inc(self, n: int | float = 1) -> None:
        assert n >= 0, f"counter increment must be >= 0, got {n}"
        self.value += n

    def snapshot(self):
        return self.value


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("value",)
    kind = "gauge"

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v

    def snapshot(self):
        return self.value


class Histogram:
    """Log-bucketed histogram: bucket k holds v in (base**(k-1), base**k]."""

    __slots__ = ("base", "buckets", "underflow", "count", "sum", "min", "max", "_log_base")
    kind = "histogram"

    def __init__(self, base: float = 2.0):
        assert base > 1.0, base
        self.base = float(base)
        self._log_base = math.log(self.base)
        self.buckets: dict[int, int] = {}
        self.underflow = 0  # v <= 0
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        if v <= 0.0:
            self.underflow += 1
            return
        # round-guard: base**k must bucket exactly on its own edge
        k = math.ceil(round(math.log(v) / self._log_base, 9))
        self.buckets[k] = self.buckets.get(k, 0) + 1

    def bucket_edges(self) -> list[tuple[float, int]]:
        """Sorted (upper_edge, count) pairs for the populated buckets."""
        return [(self.base ** k, self.buckets[k]) for k in sorted(self.buckets)]

    def percentile(self, q: float) -> float:
        """Upper edge of the bucket containing quantile q in [0, 1]
        (0.0 for the underflow bucket); conservative by construction."""
        assert 0.0 <= q <= 1.0, q
        if self.count == 0:
            return 0.0
        rank = q * (self.count - 1)
        seen = self.underflow
        if rank < seen:
            return 0.0
        for k in sorted(self.buckets):
            seen += self.buckets[k]
            if rank < seen:
                return self.base ** k
        return self.base ** max(self.buckets) if self.buckets else 0.0

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def snapshot(self):
        return dict(
            count=self.count,
            sum=self.sum,
            mean=self.mean,
            min=self.min if self.count else None,
            max=self.max if self.count else None,
            underflow=self.underflow,
            base=self.base,
            buckets=[dict(le=edge, count=c) for edge, c in self.bucket_edges()],
            p50=self.percentile(0.5),
            p90=self.percentile(0.9),
            p99=self.percentile(0.99),
        )


class MetricsRegistry:
    """Get-or-create registry of labeled metric series.

    ``counter/gauge/histogram`` return the live series object for
    ``(name, labels)`` — callers keep the handle and mutate it on the
    hot path (a dict lookup is the only registry cost).  Asking for the
    same series under a different kind is a hard error: one name means
    one thing.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._series: dict[tuple, object] = {}

    @staticmethod
    def _key(name: str, labels: dict) -> tuple:
        return (name, tuple(sorted((k, str(v)) for k, v in labels.items())))

    def _get(self, cls, name: str, labels: dict, **kw):
        key = self._key(name, labels)
        with self._lock:
            obj = self._series.get(key)
            if obj is None:
                obj = self._series[key] = cls(**kw)
            elif not isinstance(obj, cls):
                raise TypeError(
                    f"metric {name!r}{labels} already registered as "
                    f"{obj.kind}, requested {cls.kind}"
                )
            return obj

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, *, base: float = 2.0, **labels) -> Histogram:
        return self._get(Histogram, name, labels, base=base)

    # -- read side ----------------------------------------------------------

    def value(self, name: str, **labels):
        """Raw value of a counter/gauge series (None if absent)."""
        obj = self._series.get(self._key(name, labels))
        if obj is None or isinstance(obj, Histogram):
            return None
        return obj.value

    def snapshot(self) -> dict:
        """JSON-able snapshot: kind -> name -> [{labels, ...series}]."""
        out: dict[str, dict] = {"counters": {}, "gauges": {}, "histograms": {}}
        with self._lock:
            items = list(self._series.items())
        for (name, labels), obj in sorted(items, key=lambda kv: kv[0]):
            bucket = {"counter": "counters", "gauge": "gauges",
                      "histogram": "histograms"}[obj.kind]
            out[bucket].setdefault(name, []).append(
                dict(labels=dict(labels), value=obj.snapshot())
            )
        return out

    def export_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1)

    def reset(self) -> None:
        with self._lock:
            self._series.clear()


_DEFAULT = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The process-wide default registry (launchers scrape this one)."""
    return _DEFAULT


def reset_registry() -> None:
    _DEFAULT.reset()
