"""Structured event emitter — the training loop's logging plumbing.

Replaces bare ``print`` with deterministic ``[kind] key=value`` lines so
step-time regressions are greppable in training logs, while keeping the
sink injectable (tests pass ``sink=lambda s: None`` or a capture list).
Optionally mirrors every event to an append-only JSONL file, which is
the machine-readable twin the CI workflow uploads as an artifact.
"""
from __future__ import annotations

import json

__all__ = ["Emitter"]


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    if isinstance(v, (list, tuple)):
        return "/".join(_fmt(x) for x in v)
    return str(v)


class Emitter:
    """Emit structured events as human lines + optional JSONL records."""

    def __init__(self, sink=print, jsonl_path: str | None = None):
        self.sink = sink
        self._jsonl = open(jsonl_path, "a") if jsonl_path else None

    def emit(self, kind: str, **fields) -> str:
        """One event: ``[kind] k1=v1 k2=v2 ...`` (field order preserved)."""
        line = " ".join([f"[{kind}]"] + [f"{k}={_fmt(v)}" for k, v in fields.items()])
        if self.sink is not None:
            self.sink(line)
        if self._jsonl is not None:
            self._jsonl.write(json.dumps(dict(event=kind, **fields)) + "\n")
            self._jsonl.flush()
        return line

    def close(self) -> None:
        if self._jsonl is not None:
            self._jsonl.close()
            self._jsonl = None
