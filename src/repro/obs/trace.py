"""Span-based tracing — the paper's characterization harness made live.

HiHGNN's design is derived from a per-stage GPU characterization (paper
§3, Fig. 2): which stages are compute-bound, which are memory-bound, and
how much inter-semantic-graph overlap the hardware leaves on the table.
This tracer turns every launcher in this repo into that harness: spans
around the FP/theta/NA/FA stages, one *lane row* per semantic graph or
mesh lane so inter-semantic-graph structure is visible in the timeline,
and Chrome-trace/Perfetto + JSONL exporters (DESIGN.md §12).

Design constraints:

* **Near-zero cost when disabled.**  The global tracer is ``None`` by
  default; ``trace_span`` then hands back a shared no-op span and the
  decorator form calls the wrapped function directly — traced code paths
  are *bit-identical* to untraced ones (pinned by tests/test_obs.py).
* **Honest device timing.**  JAX dispatch is asynchronous, so a span
  that closes after dispatch measures nothing.  ``Span.sync(value)``
  blocks until ``value``'s device buffers are ready when the tracer was
  enabled with ``sync=True`` (and is a pass-through otherwise, and under
  ``jax.jit`` tracing, where blocking is meaningless).
* **Deterministic structure.**  Span names, attributes, nesting depth
  and parentage depend only on the code path, never on timing — the
  same program produces the same span tree on every run.

Usage::

    tracer = enable_tracing(sync=True)
    with trace_span("na/APA", stage="NA", lane="sg/APA", edges=n) as sp:
        z = neighbor_aggregate(...)
        z = sp.sync(z)          # block here, not at some later barrier
    tracer.export_chrome_trace("trace.json")   # chrome://tracing, Perfetto

    @trace_span("train/step")
    def step(state, batch): ...
"""
from __future__ import annotations

import functools
import json
import threading
import time

import jax

__all__ = [
    "Span",
    "Tracer",
    "disable_tracing",
    "enable_tracing",
    "get_tracer",
    "trace_span",
    "tracing_enabled",
]

_TRACER: "Tracer | None" = None


def _block_ready(value):
    """block_until_ready on every array leaf; pass through jit tracers
    (blocking is undefined mid-trace) and non-device values."""
    for leaf in jax.tree_util.tree_leaves(value):
        if isinstance(leaf, jax.core.Tracer):
            continue
        bur = getattr(leaf, "block_until_ready", None)
        if bur is not None:
            bur()
    return value


class Span:
    """A live span.  ``annotate`` adds attributes; ``sync`` optionally
    blocks on device values so the close timestamp is honest."""

    __slots__ = ("tracer", "name", "lane", "attrs", "depth", "parent", "t0", "_sync")

    def __init__(self, tracer, name, lane, attrs, depth, parent, sync):
        self.tracer = tracer
        self.name = name
        self.lane = lane
        self.attrs = attrs
        self.depth = depth
        self.parent = parent
        self._sync = sync
        self.t0 = 0

    def annotate(self, **attrs) -> None:
        self.attrs.update(attrs)

    def sync(self, value):
        if self._sync:
            _block_ready(value)
        return value


class _NoopSpan:
    """Shared do-nothing span handed out while tracing is disabled."""

    __slots__ = ()

    def annotate(self, **attrs) -> None:
        pass

    def sync(self, value):
        return value


_NOOP_SPAN = _NoopSpan()


class Tracer:
    """Collects finished spans; exports Chrome-trace JSON and JSONL.

    ``sync=True`` makes ``Span.sync`` block on device values (honest
    stage timing); spans may override per-span via ``trace_span(...,
    sync=False)``.  Thread-safe: each thread keeps its own span stack,
    the finished-event list and lane-row table are lock-guarded.
    """

    def __init__(self, *, sync: bool = False):
        self.sync = sync
        self.events: list[dict] = []
        self._origin_ns = time.perf_counter_ns()
        self._lock = threading.Lock()
        self._local = threading.local()
        self._lanes: dict[str, int] = {}

    # -- span lifecycle (driven by trace_span) ------------------------------

    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _lane_tid(self, lane: str) -> int:
        with self._lock:
            if lane not in self._lanes:
                self._lanes[lane] = len(self._lanes)
            return self._lanes[lane]

    def begin(self, name: str, lane: str | None, attrs: dict, sync: bool | None) -> Span:
        stack = self._stack()
        parent = stack[-1] if stack else None
        if lane is None:
            # inherit the enclosing span's row so nested stages stay on it
            lane = parent.lane if parent is not None else "main"
        sp = Span(
            self, name, lane, attrs,
            depth=len(stack),
            parent=None if parent is None else parent.name,
            sync=self.sync if sync is None else sync,
        )
        stack.append(sp)
        sp.t0 = time.perf_counter_ns()
        return sp

    def end(self, span: Span) -> None:
        t1 = time.perf_counter_ns()
        stack = self._stack()
        if stack and stack[-1] is span:
            stack.pop()
        elif span in stack:  # mis-nested close; drop it and everything above
            del stack[stack.index(span):]
        event = dict(
            name=span.name,
            ts=(span.t0 - self._origin_ns) / 1e3,   # µs since tracer start
            dur=(t1 - span.t0) / 1e3,               # µs
            lane=span.lane,
            tid=self._lane_tid(span.lane),
            depth=span.depth,
            parent=span.parent,
            attrs=span.attrs,
        )
        with self._lock:
            self.events.append(event)

    # -- exporters ----------------------------------------------------------

    def export_chrome_trace(self, path: str) -> None:
        """Chrome-trace JSON (chrome://tracing, https://ui.perfetto.dev).
        One thread row per lane — semantic graphs / mesh lanes / slots
        each get their own row, so inter-semantic-graph overlap (or its
        absence) is visible at a glance."""
        out = [dict(ph="M", name="process_name", pid=0, tid=0,
                    args=dict(name="repro"))]
        with self._lock:
            lanes = sorted(self._lanes.items(), key=lambda kv: kv[1])
            events = list(self.events)
        for lane, tid in lanes:
            out.append(dict(ph="M", name="thread_name", pid=0, tid=tid,
                            args=dict(name=str(lane))))
        for e in events:
            out.append(dict(
                name=e["name"], ph="X", pid=0, tid=e["tid"],
                ts=e["ts"], dur=e["dur"],
                cat=str(e["attrs"].get("stage", "span")),
                args=dict(e["attrs"], depth=e["depth"], parent=e["parent"]),
            ))
        with open(path, "w") as f:
            json.dump({"traceEvents": out, "displayTimeUnit": "ms"}, f, indent=1)

    def export_jsonl(self, path: str) -> None:
        """Append-only JSONL event log: one finished span per line."""
        with self._lock:
            events = list(self.events)
        with open(path, "a") as f:
            for e in events:
                f.write(json.dumps(e) + "\n")

    # -- introspection (tests) ----------------------------------------------

    def span_names(self) -> list[str]:
        with self._lock:
            return [e["name"] for e in self.events]

    def spans(self, name: str | None = None) -> list[dict]:
        with self._lock:
            return [e for e in self.events if name is None or e["name"] == name]


class trace_span:
    """Context manager AND decorator opening a span on the global tracer.

    ``lane`` picks the timeline row (default: inherit the enclosing
    span's row, else ``"main"``); ``sync`` overrides the tracer's
    block-until-ready default for this span; remaining keywords become
    span attributes (``stage=`` doubles as the Chrome-trace category).

    Disabled fast path: one attribute-store construction, a single
    global ``is None`` check, and the shared no-op span — decorated
    functions are called directly, so outputs are bit-identical.
    """

    __slots__ = ("name", "lane", "_sync", "attrs", "_span")

    def __init__(self, name: str, *, lane: str | None = None,
                 sync: bool | None = None, **attrs):
        self.name = name
        self.lane = lane
        self._sync = sync
        self.attrs = attrs
        self._span = None

    def __enter__(self):
        tr = _TRACER
        if tr is None:
            return _NOOP_SPAN
        self._span = tr.begin(self.name, self.lane, dict(self.attrs), self._sync)
        return self._span

    def __exit__(self, exc_type, exc, tb):
        sp = self._span
        if sp is not None:
            self._span = None
            sp.tracer.end(sp)
        return False

    def __call__(self, fn):
        name, lane, sync, attrs = self.name, self.lane, self._sync, self.attrs

        @functools.wraps(fn)
        def wrapped(*args, **kwargs):
            if _TRACER is None:
                return fn(*args, **kwargs)
            with trace_span(name, lane=lane, sync=sync, **attrs) as sp:
                return sp.sync(fn(*args, **kwargs))

        return wrapped


def enable_tracing(*, sync: bool = False) -> Tracer:
    """Install a fresh global tracer and return it."""
    global _TRACER
    _TRACER = Tracer(sync=sync)
    return _TRACER


def disable_tracing() -> None:
    """Drop the global tracer; trace_span reverts to the no-op fast path."""
    global _TRACER
    _TRACER = None


def get_tracer() -> Tracer | None:
    return _TRACER


def tracing_enabled() -> bool:
    return _TRACER is not None
