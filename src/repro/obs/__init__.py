"""Observability subsystem (DESIGN.md §12): span tracing + metrics.

Two pillars:

* ``obs.trace``   — span-based tracer with a near-zero-cost disabled
  mode, optional ``block_until_ready`` span boundaries, and Chrome-
  trace/Perfetto + JSONL exporters (one lane row per semantic graph /
  mesh lane / serving slot).
* ``obs.metrics`` — process-wide registry of counters, gauges, and
  log-bucketed histograms with labeled series and JSON snapshots.

``obs.emit`` is the structured line emitter the training loop logs
through; ``obs.characterize`` (imported explicitly — it pulls in
``core``) measures the paper's per-stage execution bounds on live runs.
"""
from .emit import Emitter
from .metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    get_registry,
    reset_registry,
)
from .trace import (
    Span,
    Tracer,
    disable_tracing,
    enable_tracing,
    get_tracer,
    trace_span,
    tracing_enabled,
)

__all__ = [
    "Counter",
    "Emitter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Span",
    "Tracer",
    "disable_tracing",
    "enable_tracing",
    "get_registry",
    "get_tracer",
    "reset_registry",
    "trace_span",
    "tracing_enabled",
]
