"""Live per-stage HGNN characterization (paper §3, Fig. 2 — measured).

HiHGNN's bound-aware fusion and lane scheduling were derived from a GPU
characterization of per-stage execution: FP and theta dense/compute-
bound, NA sparse/memory-bound, semantic fusion (FA) small but barrier-
prone.  ``core/stages.py`` carries that as an *analytical* model; this
module measures it on the live program: each stage runs eagerly with
``block_until_ready`` span boundaries, one trace lane per semantic graph
so the per-graph NA cost spread (the lane-balance problem) is visible in
the exported timeline.

The harness expects HAN-layout parameters (shared ``w_fp``/``b_fp``,
stacked per-graph ``a_src``/``a_dst`` — what ``models/hgnn/han.py`` and
the serving engine both use) and runs one forward worth of work.  It is
a measurement pass, not a training path: launchers invoke it once under
``--trace`` before handing off to the jitted steady state.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from ..core import stages
from ..core.fusion import NABackend, neighbor_aggregate
from .metrics import MetricsRegistry, get_registry
from .trace import trace_span

__all__ = ["characterize_hgnn"]

# span taxonomy (DESIGN.md §12): stage attr -> paper stage
STAGES = ("FP", "theta", "NA", "FA")


def _timed(name: str, stage: str, lane: str | None, fn, **attrs):
    """Run fn() under a sync span; return (value, wall µs)."""
    with trace_span(name, stage=stage, lane=lane, sync=True, **attrs) as sp:
        t0 = time.perf_counter_ns()
        out = sp.sync(fn())
        dt_us = (time.perf_counter_ns() - t0) / 1e3
    return out, dt_us


def characterize_hgnn(
    params,
    data,
    *,
    backend: NABackend = NABackend.BLOCK,
    leaky_slope: float = 0.2,
    registry: MetricsRegistry | None = None,
) -> dict:
    """Measure one eager forward stage by stage.

    Returns ``{"stage_us": {FP, theta, NA, FA}, "na_us_per_graph":
    {name: µs}, "total_us": float}`` and records each stage into the
    ``char.stage_us`` histogram (labeled by stage) of ``registry``.
    Under an enabled tracer this emits the spans the acceptance trace
    needs: one ``char/na/<graph>`` span per semantic graph on its own
    ``sg/<graph>`` lane, plus FP/theta/FA spans on the host lane.
    """
    reg = registry or get_registry()
    x = data.features[data.target_type]
    heads = params["a_src"].shape[1]
    n = x.shape[0]
    stage_us = dict.fromkeys(STAGES, 0.0)
    na_per_graph: dict[str, float] = {}

    with trace_span("char/forward", lane="host", graphs=len(data.graphs),
                    backend=backend.value):
        h, dt = _timed(
            "char/fp", "FP", "host",
            lambda: stages.feature_projection(x, params["w_fp"], params["b_fp"]),
            rows=n, d_out=int(params["w_fp"].shape[1]),
        )
        stage_us["FP"] += dt
        hh = h.reshape(n, heads, -1)

        z_list, w_list = [], []
        valid = jnp.ones((n,), bool)
        for i, batch in enumerate(data.graphs):
            lane = f"sg/{batch.name}"
            (th_s, th_d), dt = _timed(
                f"char/theta/{batch.name}", "theta", lane,
                lambda i=i: stages.attention_coefficients(
                    hh, params["a_src"][i], params["a_dst"][i]
                ),
                graph=batch.name,
            )
            stage_us["theta"] += dt

            z, dt = _timed(
                f"char/na/{batch.name}", "NA", lane,
                lambda b=batch, s=th_s, d=th_d: neighbor_aggregate(
                    b, s, d, hh, backend=backend, leaky_slope=leaky_slope
                ),
                graph=batch.name, edges=batch.num_edges, backend=backend.value,
            )
            stage_us["NA"] += dt
            na_per_graph[batch.name] = dt
            z = jax.nn.elu(z.reshape(n, -1))

            w_p, dt = _timed(
                f"char/lsf/{batch.name}", "FA", lane,
                lambda z=z: stages.local_semantic_fusion(
                    z, params["w_g"], params["b_g"], params["q"], valid
                ),
                graph=batch.name,
            )
            stage_us["FA"] += dt
            z_list.append(z)
            w_list.append(w_p)

        _, dt = _timed(
            "char/gsf", "FA", "host",
            lambda: stages.global_semantic_fusion(jnp.stack(w_list), jnp.stack(z_list)),
        )
        stage_us["FA"] += dt

    for stg, us in stage_us.items():
        reg.histogram("char.stage_us", stage=stg).observe(us)
    return dict(
        stage_us=stage_us,
        na_us_per_graph=na_per_graph,
        total_us=sum(stage_us.values()),
    )
