"""LR schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(
    step: jnp.ndarray,
    *,
    peak_lr: float,
    warmup_steps: int = 1000,
    total_steps: int = 100_000,
    min_ratio: float = 0.1,
) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(warmup_steps, 1)
    t = (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return peak_lr * jnp.where(step < warmup_steps, warm, cos)
