"""AdamW with production memory posture.

* Optimizer state inherits the parameters' logical sharding — with
  fsdp-sharded params this is ZeRO-3: state bytes scale 1/(data×model).
* ``moment_dtype=bfloat16`` halves moment memory (grok/dbrx need it to fit
  16 GB/chip, DESIGN.md §7).
* bf16 params keep an fp32 master copy in the state; the bf16 working copy
  is re-derived each step (the "gradient compression" trick is the bf16
  gradient all-reduce the SPMD partitioner emits for bf16 grads).
* Global-norm clipping.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"
    master_fp32: bool = True  # keep fp32 master when params are low-precision
    # Adafactor-style memory mode for >100B models (DESIGN.md §7): no first
    # moment, second moment factored over the last two dims (row/col means).
    # State drops from 8-12 bytes/param to ~0 bytes/param.
    factored: bool = False


def _needs_master(p, cfg: AdamWConfig) -> bool:
    return cfg.master_fp32 and p.dtype != jnp.float32


def _factorable(p) -> bool:
    return p.ndim >= 2 and p.shape[-1] > 1 and p.shape[-2] > 1


def init_opt_state(params, cfg: AdamWConfig):
    mdt = jnp.dtype(cfg.moment_dtype)
    if cfg.factored:
        return {
            "v_row": jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape[:-1], jnp.float32) if _factorable(p) else None,
                params,
            ),
            "v_col": jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                if _factorable(p) else None,
                params,
            ),
            "v_full": jax.tree_util.tree_map(
                lambda p: None if _factorable(p) else jnp.zeros(p.shape, jnp.float32),
                params,
            ),
            "master": jax.tree_util.tree_map(
                lambda p: p.astype(jnp.float32) if _needs_master(p, cfg) else None, params
            ),
            "count": jnp.zeros((), jnp.int32),
        }
    return {
        "m": jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, mdt), params),
        "v": jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, mdt), params),
        "master": jax.tree_util.tree_map(
            lambda p: p.astype(jnp.float32) if _needs_master(p, cfg) else None, params
        ),
        "count": jnp.zeros((), jnp.int32),
    }


def opt_state_axes(param_axes, cfg: AdamWConfig, params_abstract=None):
    """Logical axes for the optimizer state (mirrors the params)."""
    is_axes = lambda a: isinstance(a, tuple) and all(isinstance(x, (str, type(None))) for x in a)
    same = jax.tree_util.tree_map(lambda a: a, param_axes, is_leaf=is_axes)
    master = same
    if params_abstract is not None:
        master = jax.tree_util.tree_map(
            lambda a, p: a if _needs_master(p, cfg) else None,
            param_axes, params_abstract, is_leaf=is_axes,
        )
    if cfg.factored:
        assert params_abstract is not None, "factored axes need abstract params"
        row = jax.tree_util.tree_map(
            lambda a, p: tuple(a[:-1]) if _factorable(p) else None,
            param_axes, params_abstract, is_leaf=is_axes,
        )
        col = jax.tree_util.tree_map(
            lambda a, p: tuple(a[:-2]) + (a[-1],) if _factorable(p) else None,
            param_axes, params_abstract, is_leaf=is_axes,
        )
        full = jax.tree_util.tree_map(
            lambda a, p: None if _factorable(p) else a,
            param_axes, params_abstract, is_leaf=is_axes,
        )
        return {"v_row": row, "v_col": col, "v_full": full, "master": master, "count": ()}
    return {"m": same, "v": same, "master": master, "count": ()}


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def apply_updates(params, grads, state, cfg: AdamWConfig, lr: jnp.ndarray):
    """One optimizer step.  Returns (params, state, grad_norm)."""
    if cfg.factored:
        return _apply_factored(params, grads, state, cfg, lr)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    count = state["count"] + 1
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m32 / c1
        vhat = v32 / c2
        base = master if master is not None else p.astype(jnp.float32)
        step = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * base
        new_base = base - lr * step
        new_p = new_base.astype(p.dtype)
        new_master = new_base if master is not None else None
        return new_p, m32.astype(mdt), v32.astype(mdt), new_master

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_ma = treedef.flatten_up_to(state["master"])
    outs = [upd(*args) for args in zip(flat_p, flat_g, flat_m, flat_v, flat_ma)]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    new_state = {
        "m": jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs]),
        "v": jax.tree_util.tree_unflatten(treedef, [o[2] for o in outs]),
        "master": jax.tree_util.tree_unflatten(treedef, [o[3] for o in outs]),
        "count": count,
    }
    return new_params, new_state, gnorm


def _apply_factored(params, grads, state, cfg: AdamWConfig, lr: jnp.ndarray):
    """Adafactor-style update: factored second moment, no first moment."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    count = state["count"] + 1
    b2 = cfg.b2

    def upd(p, g, vr, vc, vf, master):
        g = g.astype(jnp.float32) * scale
        g2 = g * g + 1e-30
        if vr is not None:
            vr = b2 * vr + (1 - b2) * g2.mean(axis=-1)
            vc = b2 * vc + (1 - b2) * g2.mean(axis=-2)
            # V ≈ (R C) / mean(R): rank-1 reconstruction (Shazeer & Stern '18)
            denom = vr.mean(axis=-1, keepdims=True)
            vhat = (vr / jnp.maximum(denom, 1e-30))[..., None] * vc[..., None, :]
            vf_new = None
        else:
            vf = b2 * vf + (1 - b2) * g2
            vhat = vf
            vf_new = vf
        base = master if master is not None else p.astype(jnp.float32)
        step = g * jax.lax.rsqrt(vhat + cfg.eps) + cfg.weight_decay * base
        new_base = base - lr * step
        new_master = new_base if master is not None else None
        return new_base.astype(p.dtype), vr, vc, vf_new, new_master

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    fl = lambda t: treedef.flatten_up_to(t)
    outs = [
        upd(*args)
        for args in zip(
            flat_p, fl(grads), fl(state["v_row"]), fl(state["v_col"]),
            fl(state["v_full"]), fl(state["master"]),
        )
    ]
    unf = lambda i: jax.tree_util.tree_unflatten(treedef, [o[i] for o in outs])
    new_state = {
        "v_row": unf(1), "v_col": unf(2), "v_full": unf(3), "master": unf(4),
        "count": count,
    }
    return unf(0), new_state, gnorm
