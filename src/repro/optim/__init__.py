from .adamw import AdamWConfig, init_opt_state, apply_updates, opt_state_axes
from .schedules import warmup_cosine

__all__ = [
    "AdamWConfig",
    "init_opt_state",
    "apply_updates",
    "opt_state_axes",
    "warmup_cosine",
]
