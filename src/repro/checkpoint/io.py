"""Checkpointing: atomic, manifest-driven, elastic.

Layout: <dir>/step_<n>/ with one .npy per leaf (keyed by the flattened
tree path) and a manifest.json describing the tree, shapes, dtypes and
auxiliary state (data-pipeline counters).  Writes go to a tmp dir +
os.replace — a crash mid-write never corrupts the latest checkpoint
(fault-tolerance contract, tests/test_train).

Elastic restart: leaves are stored as *logical* (unsharded) arrays, so a
checkpoint written on one mesh restores onto any other mesh/topology —
``reshard_to`` device_puts with the new shardings (tests cover a 1-device
round-trip through a differently-sharded jit).

On a real multi-host pod each host writes its addressable shards and the
manifest records the global shape; the single-process layout here is the
degenerate case of that design.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(p) for p in path) for path, _ in flat]
    vals = [v for _, v in flat]
    return keys, vals, treedef


def save_checkpoint(ckpt_dir: str, step: int, state, aux: dict | None = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    keys, vals, _ = _flatten(state)
    manifest = {"step": step, "aux": aux or {}, "leaves": []}
    for i, (k, v) in enumerate(zip(keys, vals)):
        if v is None:
            manifest["leaves"].append({"key": k, "file": None})
            continue
        arr = np.asarray(v)
        fname = f"leaf_{i}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"key": k, "file": fname, "dtype": str(arr.dtype), "shape": list(arr.shape)}
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, like) -> tuple[object, dict]:
    """Restore into the structure of ``like`` (a pytree of arrays/None)."""
    path = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    keys, vals, treedef = _flatten(like)
    by_key = {l["key"]: l for l in manifest["leaves"]}
    out = []
    for k, v in zip(keys, vals):
        leaf = by_key[k]
        if leaf["file"] is None:
            out.append(None)
            continue
        arr = np.load(os.path.join(path, leaf["file"]))
        out.append(arr)
    state = jax.tree_util.tree_unflatten(treedef, out)
    return state, manifest["aux"]


def reshard_to(state, shardings):
    """Elastic restart: place a (host) state onto a new mesh layout."""
    return jax.tree_util.tree_map(
        lambda x, s: x if x is None else jax.device_put(x, s), state, shardings
    )
