"""Checkpointing: atomic, manifest-driven, elastic.

Layout: <dir>/step_<n>/ with one .npy per leaf (keyed by the flattened
tree path) and a manifest.json describing the tree, shapes, dtypes and
auxiliary state (data-pipeline counters).  Writes go to a tmp dir +
os.replace — a crash mid-write never corrupts the latest checkpoint
(fault-tolerance contract, tests/test_train).

Elastic restart: leaves are stored as *logical* (unsharded) arrays, so a
checkpoint written on one mesh restores onto any other mesh/topology —
``reshard_to`` device_puts with the new shardings (tests cover a 1-device
round-trip through a differently-sharded jit).

On a real multi-host pod each host writes its addressable shards and the
manifest records the global shape; the single-process layout here is the
degenerate case of that design.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(p) for p in path) for path, _ in flat]
    vals = [v for _, v in flat]
    return keys, vals, treedef


def save_checkpoint(ckpt_dir: str, step: int, state, aux: dict | None = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    keys, vals, _ = _flatten(state)
    manifest = {"step": step, "aux": aux or {}, "leaves": []}
    for i, (k, v) in enumerate(zip(keys, vals)):
        if v is None:
            manifest["leaves"].append({"key": k, "file": None})
            continue
        arr = np.asarray(v)
        fname = f"leaf_{i}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"key": k, "file": fname, "dtype": str(arr.dtype), "shape": list(arr.shape)}
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(
    ckpt_dir: str, step: int, like, *, shardings=None
) -> tuple[object, dict]:
    """Restore into the structure of ``like`` (a pytree of arrays/None).

    With ``shardings`` (a matching pytree of ``jax.sharding.Sharding``),
    leaves are placed straight onto the target mesh as they load — the
    elastic-restart path: the saved leaves are *logical* arrays, so the
    mesh they land on is free to differ from the mesh that wrote them
    (more lanes, fewer lanes, different model split).
    """
    path = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    keys, vals, treedef = _flatten(like)
    by_key = {l["key"]: l for l in manifest["leaves"]}
    out = []
    for k, v in zip(keys, vals):
        leaf = by_key[k]
        if leaf["file"] is None:
            out.append(None)
            continue
        arr = np.load(os.path.join(path, leaf["file"]))
        out.append(arr)
    state = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        state = reshard_to(state, shardings)
    return state, manifest["aux"]


def reshard_to(state, shardings=None, *, mesh=None, rules=None, axes=None):
    """Elastic restart: place a (host) state onto a new mesh layout.

    Two forms:

    * ``reshard_to(state, shardings)`` — explicit pytree of Shardings;
    * ``reshard_to(state, mesh=..., rules=..., axes=...)`` — derive the
      shardings from logical axes via ``dist.param_shardings``.  This is
      the lane-elastic form (paper §4.2.1: hardware added between runs):
      the same logical-axes tree resolves against whatever lane-mesh
      geometry the new run has, so a run checkpointed on an L-lane mesh
      restores onto an L′-lane mesh without a conversion step.  Params
      are lane-replicated under the "lanes" rules and the multilane plan
      is rebuilt per run, so the restored bits are identical for any L′
      and the continued trajectory is bitwise reproducible per topology
      (cross-topology gradients agree to f32 tolerance — the lane
      partition groups the cross-unit grad reduction;
      tests/test_hgnn_train pins both).
    """
    if shardings is None:
        assert mesh is not None and rules is not None and axes is not None, (
            "reshard_to needs either explicit shardings or a (mesh, rules, axes) triple"
        )
        from ..dist.sharding import param_shardings

        shardings = param_shardings(mesh, rules, axes)
    return jax.tree_util.tree_map(
        lambda x, s: x if x is None else jax.device_put(x, s), state, shardings
    )
