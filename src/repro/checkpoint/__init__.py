from .io import save_checkpoint, restore_checkpoint, latest_step, reshard_to

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step", "reshard_to"]
