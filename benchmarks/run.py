"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived[,backend=...]`` CSV rows:
  breakdown/*        — Fig. 2  execution-time breakdown (FP/NA/SF)
  fusion/*           — Fig. 13 bound-aware stage fusion vs staged
  lanes/*            — Fig. 14 lane scaling + workload-aware scheduling
  similarity/*       — Fig. 15 similarity-aware scheduling (DRAM fetch)
  kernel/*           — kernel-level backends (fused online-softmax NA)
  multilane/*        — fused multigraph kernel vs vmap reference vs
                       per-graph loop across G semantic graphs
  fp_cache/*         — serving-tier FP cache: hit rate vs capacity,
                       similarity vs FIFO admission (measured Fig. 15)
  stage_fusion/*     — FP+NA stage-fusion megakernel vs materialize-
                       then-NA vs staged reference (Alg. 2, DESIGN.md §10)
  hgnn_train/*       — mesh-scale training launcher: measured step time +
                       loss trajectory, plus the lane-vs-model mesh-split
                       autotune sweep (collective-vs-compute crossover)
  roofline/*         — §Roofline terms per (arch × shape × mesh), from
                       the dry-run artifacts (run launch/dryrun first)
  obs_overhead/*     — tracing/metrics layer overhead: traced-off vs
                       traced-on step time + raw span cost (DESIGN.md §12)

``--json`` additionally writes the rows as ``BENCH_<only>.json`` (or
``BENCH.json`` for a full run): a list of
``{name, us_per_call, backend, derived}`` records — the regression
baseline later PRs compare against.  Rows measured with ``timeit_stats``
also carry ``p10_us/p50_us/p90_us/iters`` so spread is separable from
regression.  ``--list`` prints the registered bench names; duplicate
registrations abort the run.
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback

from .common import row


def _registry() -> dict:
    from . import (
        breakdown,
        fp_cache,
        fusion_ablation,
        hgnn_train,
        kernels_bench,
        lanes,
        multilane_bench,
        obs_overhead,
        roofline,
        similarity,
        stage_fusion,
        stage_roofline,
    )

    benches: dict = {}

    def register(name: str, fn) -> None:
        # fail LOUDLY: a silent overwrite would drop a whole bench family
        # from the regression baseline without any signal in CI
        if name in benches:
            raise SystemExit(f"duplicate benchmark registration: {name!r}")
        benches[name] = fn

    register("breakdown", breakdown.run)
    register("fusion", fusion_ablation.run)
    register("lanes", lanes.run)
    register("similarity", similarity.run)
    register("kernels", kernels_bench.run)
    register("multilane", multilane_bench.run)
    register("fp_cache", fp_cache.run)
    register("stage_fusion", stage_fusion.run)
    register("hgnn_train", hgnn_train.run)
    register("stage_roofline", stage_roofline.run)
    register("roofline", roofline.run)
    register("obs_overhead", obs_overhead.run)
    return benches


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-list of bench names")
    ap.add_argument(
        "--json", action="store_true",
        help="write rows to BENCH_<only>.json (BENCH.json for a full run)",
    )
    ap.add_argument(
        "--list", action="store_true", help="list registered benches and exit"
    )
    args = ap.parse_args()

    benches = _registry()
    if args.list:
        for name in benches:
            print(name)
        return
    if args.only:
        keep = set(args.only.split(","))
        unknown = keep - set(benches)
        if unknown:
            raise SystemExit(f"unknown benches: {sorted(unknown)} (see --list)")
        benches = {k: v for k, v in benches.items() if k in keep}

    records: list[dict] = []

    def report(
        name: str,
        us_per_call: float,
        derived: str,
        backend: str | None = None,
        stats: tuple[float, float, float, int] | None = None,
    ):
        rec = dict(
            name=name, us_per_call=float(us_per_call), backend=backend, derived=derived,
        )
        if stats is not None:
            rec.update(
                p10_us=float(stats[0]), p50_us=float(stats[1]),
                p90_us=float(stats[2]), iters=int(stats[3]),
            )
        records.append(rec)
        return row(name, us_per_call, derived, backend=backend, stats=stats)

    failures = 0
    for name, fn in benches.items():
        try:
            fn(report)
        except Exception:
            failures += 1
            print(f"{name},0.0,ERROR", file=sys.stderr)
            traceback.print_exc()
    if args.json:
        tag = "_" + "_".join(sorted(benches)) if args.only else ""
        path = f"BENCH{tag}.json"
        with open(path, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {path} ({len(records)} rows)", file=sys.stderr)
    if failures:
        raise SystemExit(f"{failures} benches failed")


if __name__ == "__main__":
    main()
