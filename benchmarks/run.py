"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived[,backend=...]`` CSV rows:
  breakdown/*        — Fig. 2  execution-time breakdown (FP/NA/SF)
  fusion/*           — Fig. 13 bound-aware stage fusion vs staged
  lanes/*            — Fig. 14 lane scaling + workload-aware scheduling
  similarity/*       — Fig. 15 similarity-aware scheduling (DRAM fetch)
  kernel/*           — kernel-level backends (fused online-softmax NA)
  multilane/*        — fused multigraph kernel vs vmap reference vs
                       per-graph loop across G semantic graphs
  fp_cache/*         — serving-tier FP cache: hit rate vs capacity,
                       similarity vs FIFO admission (measured Fig. 15)
  stage_fusion/*     — FP+NA stage-fusion megakernel vs materialize-
                       then-NA vs staged reference (Alg. 2, DESIGN.md §10)
  hgnn_train/*       — mesh-scale training launcher: measured step time +
                       loss trajectory, plus the lane-vs-model mesh-split
                       autotune sweep (collective-vs-compute crossover)
  roofline/*         — §Roofline terms per (arch × shape × mesh), from
                       the dry-run artifacts (run launch/dryrun first)

``--json`` additionally writes the rows as ``BENCH_<only>.json`` (or
``BENCH.json`` for a full run): a list of
``{name, us_per_call, backend, derived}`` records — the regression
baseline later PRs compare against.
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback

from .common import row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-list of bench names")
    ap.add_argument(
        "--json", action="store_true",
        help="write rows to BENCH_<only>.json (BENCH.json for a full run)",
    )
    args = ap.parse_args()

    from . import (
        breakdown,
        fp_cache,
        fusion_ablation,
        hgnn_train,
        kernels_bench,
        lanes,
        multilane_bench,
        roofline,
        similarity,
        stage_fusion,
        stage_roofline,
    )

    benches = {
        "breakdown": breakdown.run,
        "fusion": fusion_ablation.run,
        "lanes": lanes.run,
        "similarity": similarity.run,
        "kernels": kernels_bench.run,
        "multilane": multilane_bench.run,
        "fp_cache": fp_cache.run,
        "stage_fusion": stage_fusion.run,
        "hgnn_train": hgnn_train.run,
        "stage_roofline": stage_roofline.run,
        "roofline": roofline.run,
    }
    if args.only:
        keep = set(args.only.split(","))
        benches = {k: v for k, v in benches.items() if k in keep}

    records: list[dict] = []

    def report(name: str, us_per_call: float, derived: str, backend: str | None = None):
        records.append(dict(
            name=name, us_per_call=float(us_per_call), backend=backend, derived=derived,
        ))
        return row(name, us_per_call, derived, backend=backend)

    failures = 0
    for name, fn in benches.items():
        try:
            fn(report)
        except Exception:
            failures += 1
            print(f"{name},0.0,ERROR", file=sys.stderr)
            traceback.print_exc()
    if args.json:
        tag = "_" + "_".join(sorted(benches)) if args.only else ""
        path = f"BENCH{tag}.json"
        with open(path, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {path} ({len(records)} rows)", file=sys.stderr)
    if failures:
        raise SystemExit(f"{failures} benches failed")


if __name__ == "__main__":
    main()
