"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  breakdown/*        — Fig. 2  execution-time breakdown (FP/NA/SF)
  fusion/*           — Fig. 13 bound-aware stage fusion vs staged
  lanes/*            — Fig. 14 lane scaling + workload-aware scheduling
  similarity/*       — Fig. 15 similarity-aware scheduling (DRAM fetch)
  kernel/*           — kernel-level backends (fused online-softmax NA)
  roofline/*         — §Roofline terms per (arch × shape × mesh), from
                       the dry-run artifacts (run launch/dryrun first)
"""
from __future__ import annotations

import argparse
import sys
import traceback

from .common import row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-list of bench names")
    args = ap.parse_args()

    from . import breakdown, fusion_ablation, kernels_bench, lanes, roofline, similarity, stage_roofline

    benches = {
        "breakdown": breakdown.run,
        "fusion": fusion_ablation.run,
        "lanes": lanes.run,
        "similarity": similarity.run,
        "kernels": kernels_bench.run,
        "stage_roofline": stage_roofline.run,
        "roofline": roofline.run,
    }
    if args.only:
        keep = set(args.only.split(","))
        benches = {k: v for k, v in benches.items() if k in keep}

    failures = 0
    for name, fn in benches.items():
        try:
            fn(row)
        except Exception:
            failures += 1
            print(f"{name},0.0,ERROR", file=sys.stderr)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} benches failed")


if __name__ == "__main__":
    main()
