"""Fig. 2 — execution-time breakdown of FP / NA / SF per HGNN model.

Each stage group is timed as its own jitted program with host barriers
(the staged execution GPU frameworks exhibit), on synthetic Table-5
datasets scaled for CPU.  The paper's finding to reproduce: NA dominates
(71.5% avg on GPU), FP second, SF small.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import stages
from repro.graphs import (
    build_semantic_graphs,
    dataset_metapaths,
    dataset_target,
    relation_semantic_graphs,
    synthetic_hetgraph,
)
from repro.models.hgnn import MODELS, prepare_data

from .common import timeit


SCALE = 0.15
HEADS = {"HAN": 8, "R-GAT": 4, "S-HGN": 4}


def _stage_fns(name, model, params, data):
    """(fp_fn, na_fn, sf_fn) per model, mirroring its forward exactly."""
    feats = data.features
    if name == "HAN":
        heads = params["a_src"].shape[1]

        @jax.jit
        def fp():
            x = feats[data.target_type]
            h = stages.feature_projection(x, params["w_fp"], params["b_fp"])
            return h.reshape(x.shape[0], heads, -1)

        hh = fp()

        @jax.jit
        def na():
            outs = []
            for i, b in enumerate(data.graphs):
                th_s, th_d = stages.attention_coefficients(hh, params["a_src"][i], params["a_dst"][i])
                z = stages.segment_softmax_aggregate(
                    b.src, b.dst, b.valid, th_s, th_d, hh, b.num_dst
                )
                outs.append(jax.nn.elu(z.reshape(b.num_dst, -1)))
            return jnp.stack(outs)

        zs = na()

        @jax.jit
        def sf():
            valid = jnp.ones((zs.shape[1],), bool)
            w_p = jnp.stack([
                stages.local_semantic_fusion(zs[p], params["w_g"], params["b_g"], params["q"], valid)
                for p in range(zs.shape[0])
            ])
            fused, _ = stages.global_semantic_fusion(w_p, zs)
            return fused @ params["w_out"] + params["b_out"]

        return fp, na, sf

    if name == "R-GCN":
        lp = params["layers"][0]

        @jax.jit
        def fp():
            return [feats[b.src_type] @ lp["rel"][f"g{i}"] for i, b in enumerate(data.graphs)]

        hr = fp()

        @jax.jit
        def na():
            return [
                stages.segment_mean_aggregate(b.src, b.dst, b.valid, hr[i], b.num_dst)
                for i, b in enumerate(data.graphs)
            ]

        zs = na()

        @jax.jit
        def sf():
            out = {}
            for t in feats:
                s = feats[t] @ lp["self"][t]
                for i, b in enumerate(data.graphs):
                    if b.dst_type == t:
                        s = s + zs[i]
                out[t] = jax.nn.relu(s)
            return out

        return fp, na, sf

    # R-GAT / S-HGN: relation-wise GAT
    heads = HEADS[name]
    lp = params["layers"][0]

    if name == "R-GAT":
        @jax.jit
        def fp():
            hs, hd = [], []
            for i, b in enumerate(data.graphs):
                rp = lp["rel"][f"g{i}"]
                hs.append((feats[b.src_type] @ rp["w_src"]).reshape(b.num_src, heads, -1))
                hd.append((feats[b.dst_type] @ rp["w_dst"]).reshape(b.num_dst, heads, -1))
            return hs, hd

        hs, hd = fp()

        @jax.jit
        def na():
            outs = []
            for i, b in enumerate(data.graphs):
                rp = lp["rel"][f"g{i}"]
                th_s, _ = stages.attention_coefficients(hs[i], rp["a_src"], rp["a_dst"])
                _, th_d = stages.attention_coefficients(hd[i], rp["a_src"], rp["a_dst"])
                z = stages.segment_softmax_aggregate(b.src, b.dst, b.valid, th_s, th_d, hs[i], b.num_dst)
                outs.append(z.reshape(b.num_dst, -1))
            return outs

        zs = na()

        @jax.jit
        def sf():
            out = {}
            for t in feats:
                zl = [zs[i] for i, b in enumerate(data.graphs) if b.dst_type == t]
                out[t] = jax.nn.elu(jnp.mean(jnp.stack(zl), 0)) if zl else feats[t]
            return out

        return fp, na, sf

    # S-HGN
    @jax.jit
    def fp():
        h = {t: feats[t] @ params["fp"][t] for t in feats}
        return {t: (h[t] @ lp["w"]).reshape(h[t].shape[0], heads, -1) for t in h}

    hproj = fp()

    @jax.jit
    def na():
        outs = []
        for i, b in enumerate(data.graphs):
            th_s, _ = stages.attention_coefficients(hproj[b.src_type], lp["a_src"], lp["a_dst"])
            _, th_d = stages.attention_coefficients(hproj[b.dst_type], lp["a_src"], lp["a_dst"])
            bias = lp["a_edge"] @ (lp["r_emb"][i] @ lp["w_r"])
            z = stages.segment_softmax_aggregate(
                b.src, b.dst, b.valid, th_s, th_d, hproj[b.src_type], b.num_dst,
                edge_bias=bias,
            )
            outs.append(z.reshape(b.num_dst, -1))
        return outs

    zs = na()

    @jax.jit
    def sf():
        out = {}
        for t in feats:
            zl = [zs[i] for i, b in enumerate(data.graphs) if b.dst_type == t]
            if zl:
                out[t] = jax.nn.elu(sum(zl))
        return out

    return fp, na, sf


def run(report):
    for ds in ("imdb", "acm", "dblp"):
        g = synthetic_hetgraph(ds, scale=SCALE, feat_scale=0.25, seed=0)
        target, ncls = dataset_target(ds)
        mp = build_semantic_graphs(g, dataset_metapaths(ds), max_edges=60_000)
        rel = relation_semantic_graphs(g)
        for name in ("HAN", "R-GCN", "R-GAT", "S-HGN"):
            data = prepare_data(
                g, mp if name == "HAN" else rel, target, ncls, with_blocks=False
            )
            model = MODELS[name]
            params = model.init(jax.random.key(0), data)
            fp, na, sf = _stage_fns(name, model, params, data)
            t_fp = timeit(fp, iters=3)
            t_na = timeit(na, iters=3)
            t_sf = timeit(sf, iters=3)
            tot = t_fp + t_na + t_sf
            report(
                f"breakdown/{ds}/{name}",
                tot,
                f"FP={t_fp/tot:.0%} NA={t_na/tot:.0%} SF={t_sf/tot:.0%}",
            )
