"""§Roofline — three-term roofline per (arch × shape × mesh) from the
dry-run artifacts (deliverable g).

  compute term    = corrected_HLO_dot_FLOPs_per_device / 197e12   (bf16 peak)
  memory term     = analytic HBM traffic per device / 819e9
  collective term = corrected collective bytes per device / 50e9  (ICI)

The memory term uses an explicit analytic traffic model (cost_analysis
"bytes accessed" does not loop-correct and mixes cache levels):
  train:  3·W/c (fwd read + bwd re-read + update write)
        + O/c (opt-state moments+master r/w)
        + A   (activation r/w: ~10 bytes·tokens·d·layers/c with full remat)
  prefill: W/c + A
  decode:  (W_active + KV)/c per token — decode reads all live weights and
           the whole KV cache once per generated token.

Also reported: MODEL_FLOPS = 6·N_act·D (train) / 2·N_act·D (inference),
the ratio MODEL_FLOPS / corrected-HLO-FLOPs (useful-compute fraction —
catches remat/redundancy waste), the dominant term, and the roofline
fraction = ideal_model_time / dominant_term (the headline score).
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import SHAPES, get_config

PEAK = 197e12
HBM = 819e9
ICI = 50e9


def _bytes_per_param(cfg):
    return 2 if cfg.param_dtype == "bfloat16" else 4


def analytic_memory_bytes(cfg, shape, chips: int, microbatches: int = 8) -> float:
    w = cfg.param_count() * _bytes_per_param(cfg)
    n_act = cfg.active_param_count()
    d, L = cfg.d_model, cfg.num_layers
    if shape.kind == "train":
        opt = cfg.param_count() * (4 + 2 + 2 if cfg.param_count() > 5e10 else 12)
        tokens = shape.global_batch * shape.seq_len
        act = 10.0 * tokens * d * L / chips  # remat: boundaries + recompute r/w
        return 3.0 * w / chips + 2.0 * opt / chips + act
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        act = 6.0 * tokens * d * L / chips
        return w / chips + act
    # decode: weights (active for MoE) + full KV/state read per token
    w_act = n_act * _bytes_per_param(cfg)
    kv = 0.0
    for i in range(L):
        pat = cfg.block_pattern[i % len(cfg.block_pattern)]
        if pat == "attn":
            kv += 2 * shape.seq_len * cfg.num_kv_heads * cfg.head_dim * 2
        elif pat == "local":
            kv += 2 * min(shape.seq_len, cfg.window or shape.seq_len) * cfg.num_kv_heads * cfg.head_dim * 2
        elif pat == "ssm":
            kv += cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 4
        elif pat == "rglru":
            kv += (cfg.rnn_width or d) * 4
    if cfg.is_encoder_decoder:
        kv += L * 2 * shape.seq_len * cfg.num_kv_heads * cfg.head_dim * 2  # self
        kv += L * 2 * cfg.encoder_seq * cfg.num_kv_heads * cfg.head_dim * 2  # cross
    kv *= shape.global_batch
    return (w_act + kv) / chips


def analytic_residency_bytes(cfg, shape, chips: int, microbatches: int = 8) -> float:
    """Peak HBM residency per chip with TPU-native dtypes.

    ``memory_analysis`` on the CPU dry-run backend over-reports bf16 cells:
    XLA:CPU hoists bf16->f32 converts of whole parameter/cache stacks out
    of the loop (no native bf16 on CPU), materializing an extra f32 copy
    that does not exist on TPU (verified in the grok decode HLO — see
    EXPERIMENTS.md §Dry-run notes).  This model is the TPU-side budget:
      train:   params + opt state + f32 grads + remat boundary stack + ws
      prefill: params + boundary-free activations + logits shard + ws
      decode:  params + KV/state cache (k+v, both buffers during update)
    """
    bpp = _bytes_per_param(cfg)
    w = cfg.param_count() * bpp / chips
    d, L = cfg.d_model, cfg.num_layers
    data_shards = 32 if chips == 512 else 16
    ws = 1.5e9  # transient working set (einsum blocks, sharded)
    if shape.kind == "train":
        if cfg.param_count() > 5e10:  # factored optimizer, no master
            opt = cfg.param_count() * 0.02 * 4
        else:
            mom = 2 if cfg.param_count() > 5e10 else 4
            master = 4 if cfg.param_dtype == "bfloat16" else 0
            opt = cfg.param_count() * (2 * mom + master)
        grads = cfg.param_count() * 4
        mb_tokens = shape.global_batch * shape.seq_len / max(microbatches, 1)
        boundaries = L * (mb_tokens / data_shards) * d * 2
        return w + (opt + grads) / chips + boundaries + ws
    if shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len / data_shards
        logits = toks * ((cfg.vocab_size + 255) // 256 * 256) / 16 * 2 / max(shape.global_batch / data_shards, 1)
        act = toks * d * 4 * 2  # few live layers' activations, bf16+f32 stats
        return w + act + min(logits, 2e9) + ws
    # decode
    kv = 0.0
    for i in range(L):
        pat = cfg.block_pattern[i % len(cfg.block_pattern)]
        if pat == "attn":
            kv += 2 * shape.seq_len * cfg.num_kv_heads * cfg.head_dim * 2
        elif pat == "local":
            kv += 2 * min(shape.seq_len, cfg.window or shape.seq_len) * cfg.num_kv_heads * cfg.head_dim * 2
        elif pat == "ssm":
            kv += cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 4 + 3 * (cfg.d_inner + 2 * cfg.ssm_state) * 2
        elif pat == "rglru":
            kv += (cfg.rnn_width or d) * 4
    if cfg.is_encoder_decoder:
        kv += L * 2 * (shape.seq_len + cfg.encoder_seq) * cfg.num_kv_heads * cfg.head_dim * 2
    kv *= shape.global_batch
    shards = 1
    if shape.global_batch % data_shards == 0 and shape.global_batch >= data_shards:
        shards *= data_shards           # batch over data
    shards *= 16                        # cache length over model (seq-sharded)
    return w + 2 * kv / shards + ws     # ×2: input + donated output buffer


def term_sentence(dom: str, cfg, shape) -> str:
    if dom == "collective":
        return "shard/schedule to cut TP all-reduces (sequence parallelism, bf16 cotangents, comm/compute overlap)"
    if dom == "memory":
        if shape.kind == "decode":
            return "decode is KV/weight-streaming bound: quantize KV, widen batch, or multi-query the cache"
        return "raise arithmetic intensity: bigger microbatches, less remat, fuse elementwise chains"
    return "compute-bound: reduce remat recompute and keep MXU-aligned shapes"


def load_cells(art_dir: str = "artifacts/dryrun") -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def compute_terms(cell: dict) -> dict | None:
    if cell.get("status") != "ok" or "arch" not in cell:
        return None  # skipped cells and non-LM artifacts (hgnn_multilane)
    cfg = get_config(cell["arch"])
    shape = SHAPES[cell["shape"]]
    chips = cell["chips"]
    mb = cell.get("microbatches", 8)
    compute_s = cell["hlo_stats"]["dot_flops_per_device"] / PEAK
    memory_s = analytic_memory_bytes(cfg, shape, chips, mb) / HBM
    coll_s = sum(cell["hlo_stats"]["collective_bytes"].values()) / ICI
    ideal_s = cell["model_flops"] / (chips * PEAK)
    dom = max(
        ("compute", compute_s), ("memory", memory_s), ("collective", coll_s),
        key=lambda kv: kv[1],
    )[0]
    bound = max(compute_s, memory_s, coll_s)
    return dict(
        arch=cell["arch"],
        shape=cell["shape"],
        mesh=cell["mesh"],
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=coll_s,
        ideal_s=ideal_s,
        dominant=dom,
        roofline_fraction=ideal_s / bound if bound else 0.0,
        useful_compute=cell["model_flops"] / max(cell["hlo_stats"]["dot_flops_per_device"] * chips, 1.0),
        mem_gib=cell["memory"]["per_device_total"] / 2**30,
        mem_fit_gib=analytic_residency_bytes(cfg, shape, chips, mb) / 2**30,
        fix=term_sentence(dom, cfg, shape),
    )


def run(report):
    cells = load_cells()
    n_ok = n_skip = 0
    for cell in cells:
        if cell.get("status") == "skipped":
            n_skip += 1
            continue
        t = compute_terms(cell)
        if t is None:
            continue
        n_ok += 1
        report(
            f"roofline/{t['arch']}/{t['shape']}/{t['mesh']}",
            t["ideal_s"] * 1e6,
            f"compute={t['compute_s']:.3g}s memory={t['memory_s']:.3g}s "
            f"collective={t['collective_s']:.3g}s dom={t['dominant']} "
            f"frac={t['roofline_fraction']:.3f} useful={t['useful_compute']:.2f} "
            f"mem={t['mem_gib']:.1f}GiB",
        )
    report("roofline/summary", 0.0, f"ok_cells={n_ok} skipped_cells={n_skip}")
    # §Perf optimized variants (recorded separately from the baseline)
    for cell in load_cells("artifacts/optimized"):
        t = compute_terms(cell)
        if t is None:
            continue
        report(
            f"roofline_optimized/{t['arch']}/{t['shape']}/{t['mesh']}",
            t["ideal_s"] * 1e6,
            f"compute={t['compute_s']:.3g}s collective={t['collective_s']:.3g}s "
            f"frac={t['roofline_fraction']:.3f} [{cell.get('optimization', '')}]",
        )
