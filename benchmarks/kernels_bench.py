"""Kernel-level microbenchmarks (CPU): fused NA backends and attention.

interpret-mode Pallas timings are NOT TPU projections — they validate the
datapath; the roofline story for TPU lives in §Roofline.  What this bench
demonstrates on CPU is the *algorithmic* win of the paper's fused
online-softmax NA: the staged segment path materializes per-edge
logits/αs (3 passes over edges), the fused block path streams them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import NABackend, batch_semantic_graph, neighbor_aggregate
from repro.graphs import build_semantic_graph, synthetic_hetgraph

from .common import timeit


def run(report):
    g = synthetic_hetgraph("dblp", scale=0.12, feat_scale=0.1, seed=0)
    sg = build_semantic_graph(g, ("author", "paper", "author"), max_edges=120_000)
    batch = batch_semantic_graph(sg, block=32)
    rng = np.random.default_rng(0)
    H, Dh = 4, 16
    hs = jnp.asarray(rng.standard_normal((sg.num_src, H, Dh)).astype(np.float32))
    ths = jnp.asarray(rng.standard_normal((sg.num_src, H)).astype(np.float32))
    thd = jnp.asarray(rng.standard_normal((sg.num_dst, H)).astype(np.float32))

    for backend in (NABackend.SEGMENT, NABackend.BLOCK):
        fn = jax.jit(
            lambda a, b, c: neighbor_aggregate(batch, a, b, c, backend=backend)
        )
        t = timeit(fn, ths, thd, hs, iters=3)
        report(
            f"kernel/na/{backend.value}",
            t,
            f"edges={sg.num_edges} heads={H} dh={Dh}",
        )
    # Pallas kernel body, interpret mode (correctness-path timing only)
    fn = jax.jit(
        lambda a, b, c: neighbor_aggregate(batch, a, b, c, backend=NABackend.KERNEL_INTERPRET)
    )
    t = timeit(fn, ths, thd, hs, warmup=1, iters=1)
    report("kernel/na/pallas_interpret", t, "interpret-mode (not a TPU projection)")

    # flash attention: XLA chunked vs materialized, plus pallas interpret
    from repro.models.lm.attention import _sdpa_flash_xla, _sdpa_xla
    from repro.models.lm.config import LMConfig

    cfg = LMConfig(name="b", family="dense", num_layers=1, d_model=256, num_heads=8,
                   num_kv_heads=2, d_ff=256, vocab_size=64, head_dim=32,
                   dtype="float32", param_dtype="float32")
    B, S = 2, 1024
    q = jnp.asarray(rng.standard_normal((B, S, 8, 32)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, S, 2, 32)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, S, 2, 32)).astype(np.float32))
    mask = jnp.tril(jnp.ones((S, S), bool))[None]
    f_mat = jax.jit(lambda q_, k_, v_: _sdpa_xla(q_, k_, v_, jnp.broadcast_to(mask, (B, S, S)), cfg))
    f_chk = jax.jit(lambda q_, k_, v_: _sdpa_flash_xla(q_, k_, v_, cfg, causal=True, window=None, q_chunk=256, k_chunk=256))
    t_mat = timeit(f_mat, q, k, v, iters=3)
    t_chk = timeit(f_chk, q, k, v, iters=3)
    report("kernel/attn/materialized", t_mat, f"S={S}")
    report("kernel/attn/chunked_online", t_chk, f"S={S} ratio={t_mat/t_chk:.2f}x")

    # FP + coefficient fusion (paper Alg. 2 lines 7-8): one pass over x vs
    # separate projection + two coefficient contractions
    from repro.core import stages

    N, Din, Hh, Dhh = 1024, 512, 8, 64
    x = jnp.asarray(rng.standard_normal((N, Din)).astype(np.float32))
    wfp = jnp.asarray(rng.standard_normal((Din, Hh * Dhh)).astype(np.float32) * 0.05)
    bfp = jnp.zeros((Hh * Dhh,))
    a_s = jnp.asarray(rng.standard_normal((Hh, Dhh)).astype(np.float32))
    a_d = jnp.asarray(rng.standard_normal((Hh, Dhh)).astype(np.float32))

    @jax.jit
    def staged_fp(x_):
        hflat = stages.feature_projection(x_, wfp, bfp)
        hh = hflat.reshape(N, Hh, Dhh)
        ts, td = stages.attention_coefficients(hh, a_s, a_d)
        return hflat, ts, td

    @jax.jit
    def fused_fp(x_):
        from repro.kernels import fused_fp_coeff
        return fused_fp_coeff(x_, wfp, bfp, a_s, a_d, block_n=256, block_k=256, interpret=True)

    t_staged = timeit(staged_fp, x, iters=3)
    t_fused = timeit(fused_fp, x, warmup=1, iters=1)
    report("kernel/fp_coeff/staged_xla", t_staged, f"N={N} Din={Din}")
    report("kernel/fp_coeff/fused_pallas_interpret", t_fused,
           "interpret-mode (datapath validation, not a TPU projection)")
