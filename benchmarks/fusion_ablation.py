"""Fig. 13 / Fig. 4 — bound-aware stage fusion vs staged execution.

Staged (Fig. 4a): one jitted program per stage, host barrier between
stages — intermediate results round-trip through memory, no cross-stage
overlap (the DGL-on-GPU structure).  Fused (Fig. 4b): the whole layer is
one XLA program; FP->theta->NA->LSF fuse, XLA schedules across stage
boundaries.  The paper reports ~35% average reduction, largest (up to
50%) for the FP-heavy R-GCN/R-GAT.

What one CPU core can and cannot show: the fused win has two components —
(a) eliminating per-stage dispatch/host round-trips (measurable here:
HAN's many small stages), and (b) overlapping compute-bound with
memory-bound stages on parallel hardware engines (the accelerator/TPU
effect; NOT observable on a single core, so GEMM-dominated R-GAT shows
~0% here).  The §Roofline dry-run is where (b) lives for the TPU target.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import NABackend, stages
from repro.graphs import (
    build_semantic_graphs,
    dataset_metapaths,
    dataset_target,
    relation_semantic_graphs,
    synthetic_hetgraph,
)
from repro.models.hgnn import MODELS, prepare_data
from repro.models.hgnn.han import han_forward_staged

from .common import timeit


def _rgat_layer_fns(data, heads):
    """Single R-GAT layer as (staged stage fns, fused fn) over the same
    math.  Params are traced arguments (NOT closure constants — a fully
    closed-over fused fn would constant-fold to nothing)."""
    feats = data.features

    def fp(lp):
        hs, hd = [], []
        for i, b in enumerate(data.graphs):
            rp = lp["rel"][f"g{i}"]
            hs.append((feats[b.src_type] @ rp["w_src"]).reshape(b.num_src, heads, -1))
            hd.append((feats[b.dst_type] @ rp["w_dst"]).reshape(b.num_dst, heads, -1))
        return hs, hd

    def na(lp, hs, hd):
        outs = []
        for i, b in enumerate(data.graphs):
            rp = lp["rel"][f"g{i}"]
            th_s, _ = stages.attention_coefficients(hs[i], rp["a_src"], rp["a_dst"])
            _, th_d = stages.attention_coefficients(hd[i], rp["a_src"], rp["a_dst"])
            z = stages.segment_softmax_aggregate(
                b.src, b.dst, b.valid, th_s, th_d, hs[i], b.num_dst
            )
            outs.append(z.reshape(b.num_dst, -1))
        return outs

    def sf(zs):
        out = {}
        for t in feats:
            zl = [zs[i] for i, b in enumerate(data.graphs) if b.dst_type == t]
            if zl:
                out[t] = jax.nn.elu(sum(zl) / len(zl))
        return out

    fp_j, na_j, sf_j = jax.jit(fp), jax.jit(na), jax.jit(sf)

    def staged(lp):
        hs, hd = fp_j(lp)
        jax.block_until_ready(hs)
        zs = na_j(lp, hs, hd)
        jax.block_until_ready(zs)
        out = sf_j(zs)
        jax.block_until_ready(out)
        return out

    fused = jax.jit(lambda lp: sf(na(lp, *fp(lp))))
    return staged, fused


def run(report):
    for ds in ("imdb", "acm", "dblp"):
        g = synthetic_hetgraph(ds, scale=0.15, feat_scale=0.25, seed=0)
        target, ncls = dataset_target(ds)
        mp = build_semantic_graphs(g, dataset_metapaths(ds), max_edges=60_000)
        data = prepare_data(g, mp, target, ncls, with_blocks=False)
        model = MODELS["HAN"]
        params = model.init(jax.random.key(0), data)

        fused = jax.jit(lambda p: model.forward(p, data, backend=NABackend.SEGMENT))
        t_fused = timeit(fused, params, warmup=3, iters=7)
        t_staged = timeit(lambda p: han_forward_staged(p, data), params, warmup=3, iters=7)
        gain = 1.0 - t_fused / t_staged
        report(
            f"fusion/{ds}/HAN",
            t_fused,
            f"staged_us={t_staged:.0f} fused_us={t_fused:.0f} reduction={gain:.0%}",
        )

        # fusion ladder on block-CSR data: unfused per-graph (SEGMENT, the
        # row above) -> consolidated one-launch (MULTIGRAPH) -> fused-FP
        # megakernel (FP pulled inside the launch, DESIGN.md §10).
        # Interpret-mode: structure validation, not a TPU projection.
        data_b = prepare_data(g, build_semantic_graphs(
            g, dataset_metapaths(ds), max_edges=12_000), target, ncls, block=16)
        p_b = model.init(jax.random.key(0), data_b)
        cons = jax.jit(lambda p: model.forward(
            p, data_b, backend=NABackend.MULTIGRAPH_INTERPRET))
        t_cons = timeit(cons, p_b, warmup=1, iters=2)
        report(f"fusion/{ds}/HAN-consolidated", t_cons,
               "one multigraph launch, h' materialized (interpret-mode)",
               backend="multigraph_interpret")
        fus = jax.jit(lambda p: model.forward(
            p, data_b, backend=NABackend.FUSED_FP_INTERPRET))
        t_fus = timeit(fus, p_b, warmup=1, iters=2)
        report(f"fusion/{ds}/HAN-fused-fp", t_fus,
               f"one FP+NA megakernel launch, h' never materialized "
               f"vs_consolidated={t_cons / max(t_fus, 1e-9):.2f}x (interpret-mode)",
               backend="fused_fp_interpret")

        # R-GAT single layer (the paper's biggest fusion winner)
        rel = relation_semantic_graphs(g)
        data_r = prepare_data(g, rel, target, ncls, with_blocks=False)
        rgat = MODELS["R-GAT"]
        p_r = rgat.init(jax.random.key(1), data_r)
        staged_fn, fused_fn = _rgat_layer_fns(data_r, heads=4)
        lp = p_r["layers"][0]
        t_staged = timeit(staged_fn, lp, warmup=3, iters=7)
        t_fused = timeit(fused_fn, lp, warmup=3, iters=7)
        gain = 1.0 - t_fused / t_staged
        report(
            f"fusion/{ds}/R-GAT",
            t_fused,
            f"staged_us={t_staged:.0f} fused_us={t_fused:.0f} reduction={gain:.0%}",
        )
