"""Fig. 14 — independency-aware parallel execution: lane scaling and the
effect of workload-aware scheduling.

On one CPU core vmapped lanes cannot show wall-clock scaling, so the
speedup model is the paper's own: lanes execute in parallel, a round
finishes when its most-loaded lane finishes — speedup(L) =
total_edges / max_lane_load(L).  Measured wall time of the multilane
program is reported alongside as a correctness/overhead check.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import batch_semantic_graph
from repro.core.multilane import build_multilane_plan, multilane_na
from repro.graphs import build_semantic_graphs, dataset_metapaths, synthetic_hetgraph

from .common import timeit


def run(report):
    g = synthetic_hetgraph("dblp", scale=0.12, feat_scale=0.1, seed=0)
    sgs = build_semantic_graphs(g, dataset_metapaths("dblp"), max_edges=120_000)
    B, H, Dh = 32, 4, 16
    batches = [batch_semantic_graph(s, block=B) for s in sgs]
    G = len(batches)
    ns = batches[0].num_src
    ns_pad = ((ns + B - 1) // B) * B
    nd_pad = batches[0].num_dst_pad
    rng = np.random.default_rng(0)
    hs = jnp.asarray(np.pad(rng.standard_normal((ns, H, Dh)), ((0, ns_pad - ns), (0, 0), (0, 0))).astype(np.float32))
    ths = jnp.asarray(rng.standard_normal((G, ns_pad, H)).astype(np.float32))
    thd = jnp.asarray(rng.standard_normal((G, nd_pad, H)).astype(np.float32))

    total = sum(b.num_edges for b in batches)
    for lanes in (1, 2, 4, 8):
        for balanced in (True, False):
            plan = build_multilane_plan(batches, lanes, balanced=balanced)
            max_load = plan.lane_plan.lane_load.max()
            speedup = total / max(max_load, 1)
            fn = jax.jit(lambda p: multilane_na(p, ths, thd, hs))
            t = timeit(fn, plan, iters=2)
            tag = "balanced" if balanced else "naive"
            report(
                f"lanes/dblp/L{lanes}/{tag}",
                t,
                f"modeled_speedup={speedup:.2f} imbalance={plan.lane_plan.imbalance():.2f}",
            )
