"""Fig. 14 — independency-aware parallel execution: lane scaling and the
effect of workload-aware scheduling.

On one CPU core vmapped lanes cannot show wall-clock scaling, so the
speedup model is the paper's own: lanes execute in parallel, a round
finishes when its most-loaded lane finishes — speedup(L) =
total_edges / max_lane_load(L).  Measured wall time of the multilane
program is reported alongside as a correctness/overhead check.

``sweep_mesh_split`` is the lane-vs-model autotune for the training
launcher: for a fixed device budget it models every L×M factorization
and reports the collective-vs-compute crossover per dataset.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import batch_semantic_graph
from repro.core.multilane import build_multilane_plan, multilane_na
from repro.graphs import build_semantic_graphs, dataset_metapaths, synthetic_hetgraph

from .common import timeit

# Per-device constants for the analytic step model (order-of-magnitude TPU
# ratios; only the flops/byte RATIO moves the crossover, not the scale).
_FLOPS = 1e11      # attainable flop/s per device on the NA inner loops
_ICI_BW = 1e10     # interconnect byte/s per link (ring collectives)
_FLOP_PER_EDGE = 8  # mul+add over (H, Dh) handled separately below


def _splits(devices: int):
    return [(l, devices // l) for l in range(1, devices + 1) if devices % l == 0]


def sweep_mesh_split(
    report,
    *,
    datasets=("acm", "imdb", "dblp"),
    devices: int = 8,
    block: int = 128,
    heads: int = 8,
    head_dim: int = 64,
    d_in: int = 128,
    scale: float = 0.3,
    max_edges: int = 1_500_000,
    prefix: str = "lanes/autotune",
):
    """Model every lane×model split of a device budget per dataset.

    Step cost per device, mirroring what ``launch.hgnn_train`` actually
    shards (``multilane_na_sharded`` shards NA over the lane axis ONLY;
    the model axis shards the dense FP/SF einsum dims):

    * NA compute — the most-loaded lane's edge work under the
      workload-aware balanced plan (NOT divided by M: NA replicates
      across the model axis);
    * FP compute — the dense projection flops, divided by M;
    * collectives — the lane psum of the NA output (ring all-reduce,
      ``2(L-1)/L`` × bytes) plus the model-axis activation collective
      (``2(M-1)/M`` × bytes of the FP output).

    The crossover is per dataset: low-degree semantic graphs (acm, imdb
    metapaths) are collective-dominated — lanes buy little edge work but
    pay the full psum, so the model split wins — while dense metapath
    graphs (dblp's APCPA, avg degree ~66 at this scale) are
    compute-dominated and the lane split wins.  Emits one row per split
    and a ``.../best`` row; returns {dataset: (L, M)}.
    """
    best = {}
    for ds in datasets:
        g = synthetic_hetgraph(ds, scale=scale, feat_scale=0.1, seed=0)
        sgs = build_semantic_graphs(g, dataset_metapaths(ds), max_edges=max_edges)
        batches = [batch_semantic_graph(s, block=block) for s in sgs]
        G = len(batches)
        n_pad = batches[0].num_dst_pad
        out_bytes = G * n_pad * heads * head_dim * 4      # psum'd NA output
        act_bytes = n_pad * heads * head_dim * 4          # FP output h'
        fp_flops = n_pad * d_in * heads * head_dim * 2
        flop_per_edge = _FLOP_PER_EDGE * heads * head_dim

        costs = {}
        for lanes, msplit in _splits(devices):
            plan = build_multilane_plan(batches, lanes, balanced=True)
            max_load = int(plan.lane_plan.lane_load.max())
            na_us = max_load * flop_per_edge / _FLOPS * 1e6
            fp_us = fp_flops / (msplit * _FLOPS) * 1e6
            lane_comm_us = 2 * (lanes - 1) / lanes * out_bytes / _ICI_BW * 1e6
            model_comm_us = 2 * (msplit - 1) / msplit * act_bytes / _ICI_BW * 1e6
            total_us = na_us + fp_us + lane_comm_us + model_comm_us
            costs[(lanes, msplit)] = total_us
            report(
                f"{prefix}/{ds}/L{lanes}xM{msplit}",
                total_us,
                f"na={na_us:.1f}us fp={fp_us:.1f}us "
                f"lane_comm={lane_comm_us:.1f}us model_comm={model_comm_us:.1f}us "
                f"imbalance={plan.lane_plan.imbalance():.2f}",
            )
        pick = min(costs, key=costs.get)
        best[ds] = pick
        report(
            f"{prefix}/{ds}/best",
            costs[pick],
            f"split=L{pick[0]}xM{pick[1]} devices={devices} "
            f"avg_deg={sum(b.num_edges for b in batches) / (G * n_pad):.1f}",
        )
    return best


def run(report):
    g = synthetic_hetgraph("dblp", scale=0.12, feat_scale=0.1, seed=0)
    sgs = build_semantic_graphs(g, dataset_metapaths("dblp"), max_edges=120_000)
    B, H, Dh = 32, 4, 16
    batches = [batch_semantic_graph(s, block=B) for s in sgs]
    G = len(batches)
    ns = batches[0].num_src
    ns_pad = ((ns + B - 1) // B) * B
    nd_pad = batches[0].num_dst_pad
    rng = np.random.default_rng(0)
    hs = jnp.asarray(np.pad(rng.standard_normal((ns, H, Dh)), ((0, ns_pad - ns), (0, 0), (0, 0))).astype(np.float32))
    ths = jnp.asarray(rng.standard_normal((G, ns_pad, H)).astype(np.float32))
    thd = jnp.asarray(rng.standard_normal((G, nd_pad, H)).astype(np.float32))

    total = sum(b.num_edges for b in batches)
    for lanes in (1, 2, 4, 8):
        for balanced in (True, False):
            plan = build_multilane_plan(batches, lanes, balanced=balanced)
            max_load = plan.lane_plan.lane_load.max()
            speedup = total / max(max_load, 1)
            fn = jax.jit(lambda p: multilane_na(p, ths, thd, hs))
            t = timeit(fn, plan, iters=2)
            tag = "balanced" if balanced else "naive"
            report(
                f"lanes/dblp/L{lanes}/{tag}",
                t,
                f"modeled_speedup={speedup:.2f} imbalance={plan.lane_plan.imbalance():.2f}",
            )

    sweep_mesh_split(report)
