"""Tracing/metrics layer overhead (DESIGN.md §12 budget).

The observability contract is two-sided: with the tracer DISABLED the
instrumented code paths must cost nothing measurable (trace_span's
fast path is one global ``is None`` check), and with the tracer ENABLED
(``sync=False`` bookkeeping mode) the per-span cost must disappear into
any realistic step (budget: ≤2% of median step time).  This bench pins
both sides on the eager instrumented NA path the serving engine uses —
``neighbor_aggregate_multi`` with the BLOCK fallback, which opens one
span per semantic graph per call — plus a raw span microbench.

``sync=True`` rows are informational: blocking at every span boundary is
the *honest-timing* mode and intentionally serializes dispatch, so it is
excluded from the overhead budget.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import NABackend, batch_semantic_graph
from repro.core.fusion import neighbor_aggregate_multi
from repro.graphs import build_semantic_graphs, synthetic_hetgraph
from repro.obs import disable_tracing, enable_tracing, trace_span

from .common import timeit_stats

_POOL = [
    ("author", "paper", "author"),
    ("author", "paper", "term", "paper", "author"),
    ("author", "paper", "venue", "paper", "author"),
]

B, H, DH = 16, 2, 8
# loose CI guard — the budget claim lives in BENCH_obs_overhead.json; the
# assert only catches a broken fast path, not scheduler noise
_MAX_TRACED_RATIO = 1.10


def run(report):
    g = synthetic_hetgraph("dblp", scale=0.12, feat_scale=0.1, seed=0)
    sgs = build_semantic_graphs(g, _POOL, max_edges=60_000)
    batches = [batch_semantic_graph(s, block=B) for s in sgs]
    gn = len(batches)
    ns, nd = batches[0].num_src, batches[0].num_dst
    rng = np.random.default_rng(0)
    hs = jnp.asarray(rng.standard_normal((ns, H, DH)).astype(np.float32))
    ths = jnp.asarray(rng.standard_normal((gn, ns, H)).astype(np.float32))
    thd = jnp.asarray(rng.standard_normal((gn, nd, H)).astype(np.float32))

    def step():
        # eager instrumented path: one na/<graph> span per semantic graph
        return neighbor_aggregate_multi(
            batches, ths, thd, hs, backend=NABackend.BLOCK
        )

    disable_tracing()
    stats_off = timeit_stats(step, warmup=2, iters=9)
    report(
        "obs_overhead/step/untraced", stats_off[1],
        f"graphs={gn} spans_per_step=0", stats=stats_off,
    )

    enable_tracing(sync=False)
    try:
        stats_on = timeit_stats(step, warmup=2, iters=9)
    finally:
        disable_tracing()
    ratio = stats_on[1] / max(stats_off[1], 1e-9)
    report(
        "obs_overhead/step/traced", stats_on[1],
        f"graphs={gn} spans_per_step={gn} overhead={ratio:.4f}x",
        stats=stats_on,
    )

    enable_tracing(sync=True)
    try:
        stats_sync = timeit_stats(step, warmup=2, iters=9)
    finally:
        disable_tracing()
    report(
        "obs_overhead/step/traced_sync", stats_sync[1],
        f"graphs={gn} honest-timing mode (serialized dispatch, "
        f"excluded from the overhead budget)",
        stats=stats_sync,
    )

    # raw span cost, both sides of the contract
    def span_burst():
        for _ in range(1000):
            with trace_span("bench/span", stage="NA", k=1):
                pass
        return ()

    stats_noop = timeit_stats(span_burst, warmup=1, iters=9)
    report(
        "obs_overhead/span_cost/disabled", stats_noop[1] / 1000,
        "us per disabled trace_span enter/exit (1000-span burst)",
    )
    tracer = enable_tracing(sync=False)
    try:
        stats_live = timeit_stats(span_burst, warmup=1, iters=9)
    finally:
        disable_tracing()
    report(
        "obs_overhead/span_cost/enabled", stats_live[1] / 1000,
        f"us per recorded span (1000-span burst, {len(tracer.events)} events kept)",
    )

    assert ratio <= _MAX_TRACED_RATIO, (
        f"tracing-enabled step overhead {ratio:.3f}x exceeds the "
        f"{_MAX_TRACED_RATIO}x guard — trace_span fast path regressed?"
    )
