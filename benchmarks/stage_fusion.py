"""Stage-fusion megakernel (Alg. 2 / DESIGN.md §10) — fused FP+NA vs
materialize-then-NA vs pure-jnp reference.

Three executors over the SAME work (all semantic graphs of a HAN layer),
swept over raw feature width Din ∈ {64, 256} × graph count G ∈ {1, 3}:

* ``materialize``  — the consolidated baseline: FP projects h' = x@W+b
  into HBM, theta einsums read it back, then ONE multigraph NA launch
  (``MULTIGRAPH_INTERPRET``) consumes it.  h' round-trips through memory.
* ``fused``        — the megakernel (``FUSED_FP_INTERPRET``): raw x tiles
  stream into the NA launch and are projected on-chip; h' never
  materializes.  Same unit tables, same numbers (asserted each shape).
* ``reference``    — project + per-graph BLOCK-backend loop (pure jnp,
  G dispatches): the staged shape both fused paths replace.

Interpret-mode timings validate the datapath and the HBM-traffic
structure on CPU; they are NOT TPU projections (that story is
``FUSED_FP`` on hardware + benchmarks/stage_roofline.py's measured
overlap).  Rows carry ``backend=`` so ``run.py --json`` writes the
BENCH_stage_fusion.json regression baseline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import NABackend, batch_semantic_graph, neighbor_aggregate
from repro.core.fusion import FusedFPInputs, neighbor_aggregate_multi
from repro.graphs import build_semantic_graphs, synthetic_hetgraph

from .common import timeit

# author→author metapath pool sharing the dst space (multilane_bench idiom)
_POOL = [
    ("author", "paper", "author"),
    ("author", "paper", "term", "paper", "author"),
    ("author", "paper", "venue", "paper", "author"),
]

B, H, DH = 16, 2, 8


def run(report):
    g = synthetic_hetgraph("dblp", scale=0.12, feat_scale=0.1, seed=0)
    rng = np.random.default_rng(0)
    for g_count in (1, 3):
        sgs = build_semantic_graphs(g, _POOL[:g_count], max_edges=20_000)
        batches = [batch_semantic_graph(s, block=B) for s in sgs]
        gn = len(batches)
        ns = batches[0].num_src
        edges = sum(bb.num_edges for bb in batches)
        for din in (64, 256):
            x = jnp.asarray(rng.standard_normal((ns, din)).astype(np.float32))
            w = jnp.asarray((rng.standard_normal((din, H * DH)) / np.sqrt(din)
                             ).astype(np.float32))
            b = jnp.asarray(rng.standard_normal((H * DH,)).astype(np.float32))
            a_s = jnp.asarray(rng.standard_normal((gn, H, DH)).astype(np.float32))
            a_d = jnp.asarray(rng.standard_normal((gn, H, DH)).astype(np.float32))
            tag = f"stage_fusion/din{din}_g{gn}"
            note = f"edges={edges} din={din} interpret-mode (not a TPU projection)"

            # staged reference: project, then one BLOCK program per graph
            def reference(x_, w_, b_, a_s_, a_d_):
                h = (x_ @ w_ + b_).reshape(ns, H, DH)
                outs = []
                for i, bb in enumerate(batches):
                    th_s = jnp.einsum("nhd,hd->nh", h, a_s_[i])
                    th_d = jnp.einsum("nhd,hd->nh", h, a_d_[i])
                    outs.append(neighbor_aggregate(
                        bb, th_s[: bb.num_src], th_d[: bb.num_dst],
                        h[: bb.num_src], backend=NABackend.BLOCK))
                return jnp.stack(outs)

            # materialize-then-NA: h' lands in HBM, one multigraph launch
            def materialize(x_, w_, b_, a_s_, a_d_):
                h = (x_ @ w_ + b_).reshape(ns, H, DH)
                th_s = jnp.einsum("nhd,ghd->gnh", h, a_s_)
                th_d = jnp.einsum("nhd,ghd->gnh", h, a_d_)
                return neighbor_aggregate_multi(
                    batches, th_s, th_d, h,
                    backend=NABackend.MULTIGRAPH_INTERPRET)

            # megakernel: raw x streams in, projection happens on-chip
            def fused(x_, w_, b_, a_s_, a_d_):
                fp = FusedFPInputs.shared(x_, w_, b_, a_s_, a_d_)
                return neighbor_aggregate_multi(
                    batches, None, None, None,
                    backend=NABackend.FUSED_FP_INTERPRET, fp=fp)

            ref_j = jax.jit(reference)
            mat_j = jax.jit(materialize)
            fus_j = jax.jit(fused)
            z_mat = mat_j(x, w, b, a_s, a_d)
            z_fus = fus_j(x, w, b, a_s, a_d)
            np.testing.assert_allclose(
                np.asarray(z_fus), np.asarray(z_mat), rtol=1e-4, atol=1e-5)

            t_ref = timeit(ref_j, x, w, b, a_s, a_d, warmup=1, iters=2)
            report(f"{tag}/reference", t_ref,
                   f"dispatches={gn} {note}", backend="block")
            t_mat = timeit(mat_j, x, w, b, a_s, a_d, warmup=1, iters=2)
            report(f"{tag}/materialize", t_mat,
                   f"dispatches=1 hbm_hprime_bytes={ns * H * DH * 4} {note}",
                   backend="multigraph_interpret")
            t_fus = timeit(fus_j, x, w, b, a_s, a_d, warmup=1, iters=2)
            report(f"{tag}/fused", t_fus,
                   f"dispatches=1 hbm_hprime_bytes=0 "
                   f"vs_materialize={t_mat / max(t_fus, 1e-9):.2f}x {note}",
                   backend="fused_fp_interpret")
