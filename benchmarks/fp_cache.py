"""Cross-request FP cache: hit rate vs capacity, similarity vs FIFO
admission (paper §4.3 at the serving tier — Fig. 15's DRAM-fetch
reduction, measured on the engine instead of modeled).

Workload: an adversarial interleaved request mix over synthetic IMDB —
director-heavy, actor-heavy and keyword-heavy subgraph queries arriving
round-robin — served by ``serve/hgnn_engine.py`` with a fixed-slot batch.
Swept: FP-cache capacity as a fraction of the total projected working
set, under FIFO and similarity-aware admission.

Reported per cell: engine wall time, measured cache hit rate, reused /
fetched bytes (the measured counterpart of ``core/reuse.fp_buffer_traffic``)
and FP rows computed.  The ``claim`` rows pin the headline: at the
adversarial capacity point (target table + one intermediate table),
similarity-aware admission must cut FP-stage compute by >= 2x vs FIFO,
with outputs bit-identical to an uncached engine.

NA backends: ``block`` (pure jnp) for the sweep; one cell runs
``multigraph_interpret`` — the fused multigraph Pallas kernel in
interpret mode — to exercise the TPU datapath (``multigraph`` on
real hardware).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import NABackend
from repro.graphs import synthetic_hetgraph
from repro.serve import HGNNEngine, make_request_mix

HIDDEN, HEADS = 8, 2
OUT_BYTES = HEADS * HIDDEN * 4  # projected row, fp32

CLUSTERS = [
    [("movie", "director", "movie"), ("movie", "director", "movie", "director", "movie")],
    [("movie", "actor", "movie"), ("movie", "actor", "movie", "actor", "movie")],
    [("movie", "keyword", "movie")],
]
REPEATS = 4


def _engine(graph, admission, cache_bytes, backend=NABackend.BLOCK):
    return HGNNEngine(
        graph,
        target_type="movie",
        hidden=HIDDEN,
        heads=HEADS,
        num_slots=2,
        cache_bytes=cache_bytes,
        cache_block_rows=64,
        admission=admission,
        backend=backend,
        block=8,
        max_edges=8_000,
        seed=0,
    )


def _serve(graph, admission, cache_bytes, backend=NABackend.BLOCK):
    eng = _engine(graph, admission, cache_bytes, backend)
    for req in make_request_mix(0, CLUSTERS, repeats=REPEATS):
        eng.submit(req)
    t0 = time.perf_counter()
    finished = eng.run()
    dt = time.perf_counter() - t0
    return eng, finished, dt * 1e6


def run(report):
    graph = synthetic_hetgraph("imdb", scale=0.05, feat_scale=0.02, seed=0)
    table = {t: n * OUT_BYTES for t, n in graph.vertex_counts.items()}
    working_set = sum(table.values())

    # hit rate vs capacity sweep
    for ratio in (0.25, 0.5, 0.75, 1.0):
        cap = int(working_set * ratio)
        for admission in ("fifo", "similarity"):
            eng, _, us = _serve(graph, admission, cap)
            m = eng.metrics()
            report(
                f"fp_cache/cap{ratio}/{admission}", us,
                f"hit_rate={m['cache_hit_rate']:.3f} "
                f"reuse_frac={m['reuse_fraction']:.3f} "
                f"reused_bytes={m['reused_bytes']} fetched_bytes={m['fetched_bytes']} "
                f"fp_rows={m['fp_rows_computed']} steps={m['steps']}",
                backend="block",
            )

    # headline claim: adversarial capacity (target + one intermediate table)
    cap = table["movie"] + max(table.values()) + 64 * OUT_BYTES
    eng_f, fin_f, us_f = _serve(graph, "fifo", cap)
    eng_s, fin_s, us_s = _serve(graph, "similarity", cap)
    mf, ms = eng_f.metrics(), eng_s.metrics()
    reduction = mf["fp_rows_computed"] / max(ms["fp_rows_computed"], 1)
    assert reduction >= 2.0, (
        f"similarity admission must cut FP compute >=2x vs FIFO, got {reduction:.2f}x"
    )
    report(
        "fp_cache/claim/fifo", us_f,
        f"hit_rate={mf['cache_hit_rate']:.3f} fp_rows={mf['fp_rows_computed']} "
        f"naive_rows={mf['fp_rows_naive']}",
        backend="block",
    )
    report(
        "fp_cache/claim/similarity", us_s,
        f"hit_rate={ms['cache_hit_rate']:.3f} fp_rows={ms['fp_rows_computed']} "
        f"naive_rows={ms['fp_rows_naive']} fp_reduction_vs_fifo={reduction:.2f}x",
        backend="block",
    )

    # cached outputs must be bit-identical to uncached recomputation
    eng_0, fin_0, us_0 = _serve(graph, "fifo", 0)
    by_rid = {r.rid: np.asarray(r.result) for r in fin_0}
    identical = all(
        np.array_equal(np.asarray(r.result), by_rid[r.rid]) for r in fin_s
    ) and all(np.array_equal(np.asarray(r.result), by_rid[r.rid]) for r in fin_f)
    assert identical, "cached engine outputs diverged from uncached recomputation"
    report(
        "fp_cache/identity/uncached", us_0,
        f"bitwise_identical={identical} hit_rate={eng_0.metrics()['cache_hit_rate']:.3f}",
        backend="block",
    )

    # fused multigraph kernel path (interpret mode on CPU; TPU: multigraph)
    eng_k, fin_k, us_k = _serve(
        graph, "similarity", cap, backend=NABackend.MULTIGRAPH_INTERPRET
    )
    mk = eng_k.metrics()
    report(
        "fp_cache/kernel/similarity", us_k,
        f"hit_rate={mk['cache_hit_rate']:.3f} na_launches={mk['na_launches']} "
        f"fused_launch_per_step=1 interpret-mode (not a TPU projection)",
        backend="multigraph_interpret",
    )
