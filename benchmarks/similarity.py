"""Fig. 15 — similarity-aware execution scheduling: FP-Buf reuse vs the
ratio (total projected features / FP-Buf) and the number of semantic
graphs (4 / 8 / 12, as the paper sweeps).

FP-Buf holds *projected* features (uniform hidden dim, as in HiHGNN), so
table sizes scale with vertex counts.  Reported: normalized DRAM fetch
bytes (hamilton / random-order mean) — the paper's Fig. 15(b) — plus the
achieved reuse fraction.  Expected, and observed: limited impact at 4
semantic graphs, large reductions at 8-12 (paper §6.2).
"""
from __future__ import annotations

import numpy as np

from repro.core import fp_buffer_traffic, similarity_schedule
from repro.graphs import build_semantic_graphs, synthetic_hetgraph

from .common import timeit

HIDDEN_BYTES = 64 * 4  # projected feature row: hidden 64, fp32

# metapath pool over IMDB types (paper sweeps synthetic metapath counts)
_POOL = [
    ("movie", "director", "movie"),
    ("movie", "actor", "movie"),
    ("movie", "keyword", "movie"),
    ("director", "movie", "director"),
    ("actor", "movie", "actor"),
    ("keyword", "movie", "keyword"),
    ("director", "movie", "actor", "movie", "director"),
    ("actor", "movie", "keyword", "movie", "actor"),
    ("movie", "director", "movie", "actor", "movie"),
    ("keyword", "movie", "director", "movie", "keyword"),
    ("actor", "movie", "director", "movie", "actor"),
    ("movie", "keyword", "movie", "director", "movie"),
]


def run(report):
    g = synthetic_hetgraph("imdb", scale=0.4, feat_scale=0.1, seed=0)
    bpv = {t: HIDDEN_BYTES for t in g.vertex_counts}
    total_bytes = sum(g.vertex_counts[t] * bpv[t] for t in g.vertex_counts)
    rng = np.random.default_rng(0)
    for n_graphs in (4, 8, 12):
        sgs = build_semantic_graphs(g, _POOL[:n_graphs], max_edges=20_000)
        order, _ = similarity_schedule(sgs, g.vertex_counts)
        for ratio in (1.5, 2.0, 3.0):
            buf = int(total_bytes / ratio)
            sim = fp_buffer_traffic(
                order, sgs, g.vertex_counts, bytes_per_vertex=bpv, fpbuf_bytes=buf
            )
            rnd = [
                fp_buffer_traffic(
                    list(rng.permutation(len(sgs))), sgs, g.vertex_counts,
                    bytes_per_vertex=bpv, fpbuf_bytes=buf,
                )
                for _ in range(20)
            ]
            rnd_fetch = np.mean([r.fetched_bytes for r in rnd])
            norm = sim.fetched_bytes / max(rnd_fetch, 1)
            # wall time of one traffic-model evaluation (host-side)
            t = timeit(
                lambda: fp_buffer_traffic(
                    order, sgs, g.vertex_counts, bytes_per_vertex=bpv, fpbuf_bytes=buf
                ),
                warmup=1, iters=3,
            )
            report(
                f"similarity/imdb/P{n_graphs}/ratio{ratio}",
                t,
                f"normalized_dram_fetch={norm:.3f} reuse_frac={sim.reuse_fraction:.3f}",
            )
