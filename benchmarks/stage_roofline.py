"""Table 3 / Fig. 3 — per-stage arithmetic intensity and execution bound.

The paper profiles HAN-on-DBLP CUDA kernels: the FP sgemm has AI
26.8 FLOP/B (compute-bound, above the T4 ridge), the NA SpMMCsr has AI
0.49 FLOP/B (memory-bound).  We reproduce the *classification* for the
TPU target by compiling each stage in isolation and reading
``cost_analysis`` (flops, bytes accessed): AI = flops/bytes, compared
with the v5e ridge point 197e12/819e9 ≈ 240 FLOP/B (bf16) or the paper's
fp32-style ridge using fp32 ops.  The NA stage lands orders of magnitude
below the FP stage — the paper's core observation, and the reason its
stage fusion pairs them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import NABackend, batch_semantic_graph, stages
from repro.core.fusion import FusedFPInputs, neighbor_aggregate_multi
from repro.launch.hlostats import normalize_cost_analysis
from repro.graphs import build_semantic_graph, synthetic_hetgraph, to_padded_edges

from .common import timeit

RIDGE_V5E = 197e12 / 819e9  # ≈ 240 FLOP/byte (bf16 MXU)
RIDGE_T4 = 8.1e12 / 300e9    # ≈ 27 FLOP/byte (the paper's Fig. 3 ridge)


def _ai(fn, *args):
    """(flops, bytes, AI) from cost_analysis; bytes/AI are None when the
    backend omits "bytes accessed" — a fabricated default would silently
    misclassify the bound."""
    c = jax.jit(fn).lower(*args).compile()
    cost = normalize_cost_analysis(c.cost_analysis())
    fl = float(cost.get("flops", 0.0))
    by = cost.get("bytes accessed")
    if by is None:
        return fl, None, None
    by = float(by)
    return fl, by, fl / max(by, 1.0)


def _derived(fl, ai):
    if ai is None:
        return f"AI=n/a (backend omitted bytes accessed) flops={fl:.3g}"
    return (
        f"AI={ai:.1f}FLOP/B T4bound={'compute' if ai > RIDGE_T4 else 'memory'} "
        f"v5ebound={'compute' if ai > RIDGE_V5E else 'memory'} flops={fl:.3g}"
    )


def run(report):
    g = synthetic_hetgraph("dblp", scale=0.25, feat_scale=0.5, seed=0)
    sg = build_semantic_graph(g, ("author", "paper", "author"), max_edges=300_000)
    pe = to_padded_edges(sg)
    rng = np.random.default_rng(0)
    d_in = g.feature_dim("author")
    H, Dh = 8, 64
    x = jnp.asarray(rng.standard_normal((sg.num_src, d_in)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((d_in, H * Dh)).astype(np.float32))
    b = jnp.zeros((H * Dh,))
    a_s = jnp.asarray(rng.standard_normal((H, Dh)).astype(np.float32))
    a_d = jnp.asarray(rng.standard_normal((H, Dh)).astype(np.float32))
    h = jnp.asarray(rng.standard_normal((sg.num_src, H, Dh)).astype(np.float32))
    th_s = jnp.asarray(rng.standard_normal((sg.num_src, H)).astype(np.float32))
    th_d = jnp.asarray(rng.standard_normal((sg.num_dst, H)).astype(np.float32))
    src, dst, valid = jnp.asarray(pe.src), jnp.asarray(pe.dst), jnp.asarray(pe.valid)

    # FP stage (dense GEMM — the paper's sgemm)
    fp_fn = lambda x_: stages.feature_projection(x_, w, b)
    fl, by, ai = _ai(fp_fn, x)
    t = timeit(jax.jit(fp_fn), x, iters=3)
    report("stage_roofline/FP", t, _derived(fl, ai))
    ai_fp = ai

    # NA stage (segment softmax aggregation — the paper's SpMMCsr)
    na_fn = lambda t1, t2, h_: stages.segment_softmax_aggregate(
        src, dst, valid, t1, t2, h_, sg.num_dst
    )
    fl, by, ai = _ai(na_fn, th_s, th_d, h)
    t = timeit(jax.jit(na_fn), th_s, th_d, h, iters=3)
    report("stage_roofline/NA", t, _derived(fl, ai))
    ai_na = ai

    # SF stage (semantic attention: gemm + elementwise + reduce)
    z = jnp.asarray(rng.standard_normal((3, sg.num_dst, H * Dh)).astype(np.float32))
    w_g = jnp.asarray(rng.standard_normal((H * Dh, 128)).astype(np.float32))
    q = jnp.asarray(rng.standard_normal((128,)).astype(np.float32))

    def sf(z_):
        valid_v = jnp.ones((sg.num_dst,), bool)
        w_p = jnp.stack([
            stages.local_semantic_fusion(z_[p], w_g, jnp.zeros((128,)), q, valid_v)
            for p in range(3)
        ])
        fused, _ = stages.global_semantic_fusion(w_p, z_)
        return fused

    fl, by, ai = _ai(sf, z)
    t = timeit(jax.jit(sf), z, iters=3)
    report("stage_roofline/SF", t, _derived(fl, ai))
    # the paper's headline: FP's AI is orders of magnitude above NA's
    if ai_fp is None or ai_na is None:
        report("stage_roofline/ratio", 0.0,
               "AI_FP/AI_NA=n/a (backend omitted bytes accessed)")
    else:
        report("stage_roofline/ratio", 0.0,
               f"AI_FP/AI_NA={ai_fp/max(ai_na,1e-9):.1f}x (paper: 26.8/0.49=55x)")

    # -- measured FP/NA overlap of the stage-fusion megakernel ------------
    # The analytical rows above CLASSIFY the bound; this measures how much
    # of the cheaper stage the fused launch actually hides:
    #   overlap = (t_FP + t_NA - t_fused) / min(t_FP, t_NA)
    # 1.0 = the cheaper stage fully hidden behind the other; <=0 = fusion
    # added overhead instead (expected on the CPU interpreter, which runs
    # the pipeline stages serially — the TPU path is where Alg. 2's
    # double-buffered overlap lives).
    sg_f = build_semantic_graph(g, ("author", "paper", "author"),
                                max_edges=6_000, seed=0)
    bb = batch_semantic_graph(sg_f, block=16)
    n_pad = max(((bb.num_src + 15) // 16) * 16, bb.num_dst_pad)
    din_f, hf, dhf = 64, 2, 8
    xf = jnp.asarray(rng.standard_normal((n_pad, din_f)).astype(np.float32))
    wf = jnp.asarray((rng.standard_normal((din_f, hf * dhf)) / 8).astype(np.float32))
    bf = jnp.zeros((hf * dhf,))
    asf = jnp.asarray(rng.standard_normal((1, hf, dhf)).astype(np.float32))
    adf = jnp.asarray(rng.standard_normal((1, hf, dhf)).astype(np.float32))

    def fp_stage(x_):
        hh = (x_ @ wf + bf).reshape(n_pad, hf, dhf)
        return hh, jnp.einsum("nhd,ghd->gnh", hh, asf), jnp.einsum("nhd,ghd->gnh", hh, adf)

    def na_stage(hh, ts, td):
        return neighbor_aggregate_multi(
            [bb], ts, td, hh, backend=NABackend.MULTIGRAPH_INTERPRET)

    def fused_stage(x_):
        fp = FusedFPInputs.shared(x_, wf, bf, asf, adf)
        return neighbor_aggregate_multi(
            [bb], None, None, None, backend=NABackend.FUSED_FP_INTERPRET, fp=fp)

    hh, ts, td = jax.jit(fp_stage)(x := xf)
    t_fp = timeit(jax.jit(fp_stage), x, warmup=1, iters=2)
    t_na = timeit(jax.jit(na_stage), hh, ts, td, warmup=1, iters=2)
    t_fu = timeit(jax.jit(fused_stage), x, warmup=1, iters=2)
    overlap = (t_fp + t_na - t_fu) / max(min(t_fp, t_na), 1e-9)
    report("stage_roofline/fused_overlap", t_fu,
           f"measured_overlap_frac={overlap:.2f} fp_us={t_fp:.0f} "
           f"na_us={t_na:.0f} fused_us={t_fu:.0f} "
           f"(interpret-mode: serial pipeline, not a TPU projection)")
