"""Shared benchmark utilities."""
from __future__ import annotations

import time

import jax


def timeit(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time in MICROSECONDS of fn(*args) with block_until_ready.

    Returns µs so report rows (`us_per_call`) consume it directly —
    callers must not rescale.
    """
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def row(name: str, us_per_call: float, derived: str, backend: str | None = None) -> str:
    line = f"{name},{us_per_call:.1f},{derived}"
    if backend is not None:
        line += f",backend={backend}"
    print(line)
    return line
