"""Shared benchmark utilities."""
from __future__ import annotations

import time

import jax


def timeit_stats(
    fn, *args, warmup: int = 2, iters: int = 5
) -> tuple[float, float, float, int]:
    """``(p10, p50, p90, iters)`` wall time in MICROSECONDS of fn(*args)
    with block_until_ready.

    Single-number medians hide run-to-run spread, which is exactly what
    an observability PR needs to pin down — report rows carry the p10/p90
    envelope alongside ``us_per_call`` so a regression is separable from
    noise.  Percentiles use nearest-rank on the sorted sample (with the
    default 5 iters: p10=min, p50=median, p90=max).
    """
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()

    def q(p: float) -> float:
        # upper nearest-rank: q(0.5) == times[iters // 2], the exact
        # median the pre-stats timeit() reported for every iter count
        return times[min(iters - 1, int(p * (iters - 1) + 0.5))] * 1e6

    return q(0.10), q(0.50), q(0.90), iters


def timeit(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time in MICROSECONDS of fn(*args) with block_until_ready.

    Returns µs so report rows (`us_per_call`) consume it directly —
    callers must not rescale.
    """
    return timeit_stats(fn, *args, warmup=warmup, iters=iters)[1]


def row(
    name: str,
    us_per_call: float,
    derived: str,
    backend: str | None = None,
    stats: tuple[float, float, float, int] | None = None,
) -> str:
    line = f"{name},{us_per_call:.1f},{derived}"
    if backend is not None:
        line += f",backend={backend}"
    if stats is not None:
        line += f",p10={stats[0]:.1f},p90={stats[2]:.1f}"
    print(line)
    return line
