"""Multilane NA executors — the first measured perf trajectory for the
fused multigraph kernel (paper §4.1–4.2).

Three executors over the SAME work (all semantic graphs of a HAN layer),
swept over graph counts G ∈ {1, 3, 5}:

* ``per_graph_loop``   — one jitted BLOCK-backend program per semantic
  graph with a host barrier each (G dispatches): the staged
  GPU-framework shape the paper speeds up.
* ``vmap_reference``   — ``multilane_na`` reference backend: one dispatch,
  vmap over (lanes, units) of the scan oracle.
* ``kernel_interpret`` — ``multilane_na(backend="kernel_interpret")``:
  one dispatch containing ONE fused Pallas launch for every unit of every
  graph.  Interpret-mode timings validate the datapath and dispatch
  structure on CPU; they are NOT TPU projections (the TPU story is
  ``backend="kernel"`` on real hardware + §Roofline).

Rows carry ``backend=`` so ``run.py --json`` can write the
BENCH_multilane.json regression baseline (schema: name, us_per_call,
backend, derived).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import NABackend, batch_semantic_graph, neighbor_aggregate
from repro.core.multilane import build_multilane_plan, multilane_na
from repro.graphs import build_semantic_graphs, synthetic_hetgraph

from .common import timeit

# author→author metapath pool over DBLP (Table 5 relations); prefixes give
# the G sweep, all sharing the author dst/src space as multilane requires
_POOL = [
    ("author", "paper", "author"),
    ("author", "paper", "term", "paper", "author"),
    ("author", "paper", "venue", "paper", "author"),
    ("author", "paper", "author", "paper", "author"),
    ("author", "paper", "venue", "paper", "author", "paper", "author"),
]

B, H, DH, LANES = 16, 2, 8, 4


def run(report):
    g = synthetic_hetgraph("dblp", scale=0.12, feat_scale=0.1, seed=0)
    rng = np.random.default_rng(0)
    for g_count in (1, 3, 5):
        sgs = build_semantic_graphs(g, _POOL[:g_count], max_edges=60_000)
        batches = [batch_semantic_graph(s, block=B) for s in sgs]
        gn = len(batches)
        ns = batches[0].num_src
        ns_pad = ((ns + B - 1) // B) * B
        nd_pad = batches[0].num_dst_pad
        edges = sum(bb.num_edges for bb in batches)
        hs = jnp.asarray(
            np.pad(rng.standard_normal((ns, H, DH)), ((0, ns_pad - ns), (0, 0), (0, 0))
                   ).astype(np.float32))
        ths = jnp.asarray(rng.standard_normal((gn, ns_pad, H)).astype(np.float32))
        thd = jnp.asarray(rng.standard_normal((gn, nd_pad, H)).astype(np.float32))

        # staged shape: one program per graph, host barrier after each
        fns = [
            jax.jit(lambda a, b_, c, bb=bb: neighbor_aggregate(
                bb, a, b_, c, backend=NABackend.BLOCK))
            for bb in batches
        ]

        def per_graph_loop():
            outs = []
            for i, fn in enumerate(fns):
                bb = batches[i]
                out = fn(ths[i, : bb.num_src], thd[i, : bb.num_dst], hs[: bb.num_src])
                jax.block_until_ready(out)
                outs.append(out)
            return outs

        t_loop = timeit(per_graph_loop, iters=3)
        report(f"multilane/G{gn}/per_graph_loop", t_loop,
               f"dispatches={gn} edges={edges}", backend="block")

        plan = build_multilane_plan(batches, LANES)
        ref_fn = jax.jit(lambda p: multilane_na(p, ths, thd, hs))
        t_ref = timeit(ref_fn, plan, iters=3)
        report(f"multilane/G{gn}/vmap_reference", t_ref,
               f"dispatches=1 lanes={LANES} edges={edges} "
               f"vs_loop={t_loop/max(t_ref,1e-9):.2f}x", backend="reference")

        ker_fn = jax.jit(
            lambda p: multilane_na(p, ths, thd, hs, backend="kernel_interpret"))
        t_ker = timeit(ker_fn, plan, warmup=1, iters=1)
        report(f"multilane/G{gn}/kernel_interpret", t_ker,
               f"dispatches=1 fused_launches=1 edges={edges} "
               f"interpret-mode (not a TPU projection)",
               backend="kernel_interpret")
