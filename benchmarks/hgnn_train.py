"""HGNN training benchmark — the mesh-scale launcher end to end.

Runs a short HAN and R-GAT trajectory through ``launch.hgnn_train``'s
``run_training`` (interpret kernel backend so it executes anywhere) and
reports the measured step time plus the loss trajectory — the regression
baseline for the training path (BENCH_hgnn_train.json).  Also emits the
lane-vs-model mesh-split autotune sweep (``lanes.sweep_mesh_split``) so
the training artifact carries the split the launcher should be run with.
"""
from __future__ import annotations

from repro.launch.hgnn_train import run_training

from .lanes import sweep_mesh_split

_STEPS = 8


def run(report):
    for model_name, dataset in (("HAN", "acm"), ("R-GAT", "imdb")):
        state, history, meta = run_training(
            dataset=dataset,
            model_name=model_name,
            steps=_STEPS,
            lanes=1,
            backend="kernel",  # resolves to the interpreter on CPU hosts
            hidden=8,
            heads=2,
            scale=0.06,
            max_edges=60_000,
            log_every=1,
            log=lambda *_: None,
        )
        first, last = history[0], history[-1]
        # skip the step-0 compile; median of the steady-state step times
        secs = sorted(m["sec"] for m in history[1:])
        step_us = secs[len(secs) // 2] * 1e6
        report(
            f"hgnn_train/{dataset}/{model_name}",
            step_us,
            f"loss0={first['loss']:.4f} lossN={last['loss']:.4f} "
            f"decreasing={last['loss'] < first['loss']} steps={_STEPS} "
            f"params={meta['n_params']}",
            backend=str(meta["backend"]),
        )
        assert last["loss"] < first["loss"], (model_name, first, last)

    sweep_mesh_split(report, prefix="hgnn_train/autotune")
