"""Per-kernel interpret=True validation against ref.py oracles, sweeping
shapes and dtypes as the brief requires."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention import flash_attention
from repro.kernels.fused_fp_coeff import fused_fp_coeff
from repro.kernels.ref import ref_flash_attention, ref_fused_fp_coeff, ref_seg_gat_agg
from repro.kernels.seg_gat_agg import seg_gat_agg

TOL = {jnp.float32: dict(rtol=3e-5, atol=3e-5), jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


def _unique_cols(rng, R, W, ncols):
    """BlockCSR contract: column indices are unique within a row (-1 pad)."""
    col = np.full((R, W), -1, np.int32)
    for r in range(R):
        k = rng.integers(0, min(W, ncols) + 1)
        col[r, :k] = rng.choice(ncols, size=k, replace=False)
    return col


@pytest.mark.parametrize("B,R,W,H,Dh", [(8, 2, 1, 1, 8), (8, 3, 2, 2, 16), (16, 2, 3, 1, 32), (8, 1, 4, 4, 8)])
def test_seg_gat_agg_shapes(B, R, W, H, Dh):
    rng = np.random.default_rng(B + R + W)
    ns = 4 * B
    col = _unique_cols(rng, R, W, 4)
    masks = rng.random((R, W, B, B)) < 0.3
    ths = rng.standard_normal((ns, H)).astype(np.float32)
    thd = rng.standard_normal((R * B, H)).astype(np.float32)
    hs = rng.standard_normal((ns, H, Dh)).astype(np.float32)
    out = seg_gat_agg(
        jnp.asarray(col), jnp.asarray(masks), jnp.asarray(ths), jnp.asarray(thd),
        jnp.asarray(hs), interpret=True,
    )
    ref = ref_seg_gat_agg(
        jnp.asarray(col), jnp.asarray(masks), jnp.asarray(ths), jnp.asarray(thd), jnp.asarray(hs)
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL[jnp.float32])


def test_seg_gat_agg_edge_bias_and_all_padding():
    rng = np.random.default_rng(0)
    B, R, W, H, Dh = 8, 2, 2, 2, 8
    ns = 2 * B
    col = np.array([[0, 1], [-1, -1]], np.int32)  # second row fully padded
    masks = rng.random((R, W, B, B)) < 0.4
    ths = rng.standard_normal((ns, H)).astype(np.float32)
    thd = rng.standard_normal((R * B, H)).astype(np.float32)
    hs = rng.standard_normal((ns, H, Dh)).astype(np.float32)
    bias = jnp.asarray(rng.standard_normal(H).astype(np.float32))
    out = seg_gat_agg(
        jnp.asarray(col), jnp.asarray(masks), jnp.asarray(ths), jnp.asarray(thd),
        jnp.asarray(hs), edge_bias=bias, interpret=True,
    )
    ref = ref_seg_gat_agg(
        jnp.asarray(col), jnp.asarray(masks), jnp.asarray(ths), jnp.asarray(thd),
        jnp.asarray(hs), edge_bias=bias,
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-5, atol=3e-5)
    assert np.abs(np.asarray(out)[B:]).max() == 0.0  # padded row -> zeros


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("N,Din,H,Dh,bn,bk", [(64, 48, 2, 16, 32, 16), (32, 64, 1, 32, 32, 64), (128, 32, 4, 8, 64, 32)])
def test_fused_fp_coeff_sweep(dtype, N, Din, H, Dh, bn, bk):
    rng = np.random.default_rng(N + Din)
    x = rng.standard_normal((N, Din)).astype(np.float32) * 0.5
    w = rng.standard_normal((Din, H * Dh)).astype(np.float32) * 0.1
    b = rng.standard_normal(H * Dh).astype(np.float32) * 0.1
    a_s = rng.standard_normal((H, Dh)).astype(np.float32)
    a_d = rng.standard_normal((H, Dh)).astype(np.float32)
    args = [jnp.asarray(x, dtype), jnp.asarray(w, dtype), jnp.asarray(b, dtype),
            jnp.asarray(a_s, dtype), jnp.asarray(a_d, dtype)]
    h, ts, td = fused_fp_coeff(*args, block_n=bn, block_k=bk, interpret=True)
    rh, rts, rtd = ref_fused_fp_coeff(*args)
    tol = TOL[dtype]
    np.testing.assert_allclose(np.asarray(h, np.float32), np.asarray(rh, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(ts), np.asarray(rts, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(td), np.asarray(rtd, np.float32), **tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Hq,Hkv,Sq,Sk,Dh,causal,window",
    [
        (2, 4, 2, 32, 32, 16, True, None),
        (1, 4, 4, 16, 48, 16, True, None),   # Sq != Sk (continuation)
        (1, 2, 1, 32, 32, 16, True, 8),      # MQA + local window
        (1, 2, 2, 32, 32, 16, False, None),  # bidirectional (encoder)
        (2, 8, 2, 64, 64, 32, True, None),
    ],
)
def test_flash_attention_sweep(dtype, B, Hq, Hkv, Sq, Sk, Dh, causal, window):
    rng = np.random.default_rng(Sq + Sk)
    q = jnp.asarray(rng.standard_normal((B, Hq, Sq, Dh)).astype(np.float32), dtype)
    k = jnp.asarray(rng.standard_normal((B, Hkv, Sk, Dh)).astype(np.float32), dtype)
    v = jnp.asarray(rng.standard_normal((B, Hkv, Sk, Dh)).astype(np.float32), dtype)
    o = flash_attention(q, k, v, causal=causal, window=window, block_q=16, block_k=16, interpret=True)
    r = ref_flash_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(r, np.float32), **TOL[dtype]
    )


@settings(max_examples=10, deadline=None)
@given(st.data())
def test_flash_attention_property(data):
    """Property: output rows are convex combinations of V rows."""
    rng = np.random.default_rng(data.draw(st.integers(0, 999)))
    s = data.draw(st.sampled_from([16, 32]))
    h = data.draw(st.sampled_from([1, 2]))
    q = jnp.asarray(rng.standard_normal((1, h, s, 8)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((1, h, s, 8)).astype(np.float32))
    v = jnp.ones((1, h, s, 8), jnp.float32)
    o = flash_attention(q, k, v, causal=True, block_q=8, block_k=8, interpret=True)
    np.testing.assert_allclose(np.asarray(o), 1.0, rtol=1e-5)


def _multigraph_case(seed=7, B=8, U=4, W=3, G=3, H=2, Dh=8, nblk=4, dtype=np.float32):
    rng = np.random.default_rng(seed)
    ns_pad = nblk * B
    col = np.full((U, W), -1, np.int32)
    for u in range(U):
        k = rng.integers(1, W + 1)
        col[u, :k] = rng.choice(nblk, size=k, replace=False)
    gid = rng.integers(0, G, U).astype(np.int32)
    row = rng.integers(0, nblk, U).astype(np.int32)
    masks = rng.random((U, W, B, B)) < 0.3
    ths = rng.standard_normal((G, ns_pad, H)).astype(dtype)
    thd = rng.standard_normal((G, ns_pad, H)).astype(dtype)
    hs = rng.standard_normal((ns_pad, H, Dh)).astype(dtype)
    bias = rng.standard_normal((G, H)).astype(np.float32)
    return col, gid, row, masks, ths, thd, hs, bias


def test_seg_gat_agg_multigraph_invalid_units_are_exact_zeros():
    from repro.kernels import seg_gat_agg_multigraph

    col, gid, row, masks, ths, thd, hs, bias = _multigraph_case()
    col[1] = -1   # unit 1: every slot padded
    col[3] = -1
    out = seg_gat_agg_multigraph(
        jnp.asarray(col), jnp.asarray(gid), jnp.asarray(row), jnp.asarray(masks),
        jnp.asarray(ths), jnp.asarray(thd), jnp.asarray(hs), jnp.asarray(bias),
        interpret=True,
    )
    B = masks.shape[-1]
    out = np.asarray(out)
    assert np.abs(out[1 * B : 2 * B]).max() == 0.0
    assert np.abs(out[3 * B : 4 * B]).max() == 0.0
    assert np.abs(out[0:B]).max() > 0.0  # live units untouched


def test_seg_gat_agg_multigraph_bf16_matches_f32_oracle():
    from repro.core.multilane import _unit_na
    from repro.kernels import seg_gat_agg_multigraph

    col, gid, row, masks, ths, thd, hs, bias = _multigraph_case(seed=11)
    B = masks.shape[-1]
    out = seg_gat_agg_multigraph(
        jnp.asarray(col), jnp.asarray(gid), jnp.asarray(row), jnp.asarray(masks),
        jnp.asarray(ths), jnp.asarray(thd), jnp.asarray(hs, jnp.bfloat16),
        jnp.asarray(bias), interpret=True,
    )
    assert out.dtype == jnp.bfloat16
    for u in range(col.shape[0]):
        ref = _unit_na(
            jnp.asarray(col[u]), jnp.asarray(masks[u]), jnp.int32(gid[u]),
            jnp.int32(row[u]), jnp.asarray(ths), jnp.asarray(thd), jnp.asarray(hs),
            jnp.asarray(bias), 0.2,
        )
        np.testing.assert_allclose(
            np.asarray(out[u * B : (u + 1) * B], np.float32), np.asarray(ref),
            **TOL[jnp.bfloat16],
        )


def test_seg_gat_agg_multigraph_g1_reduces_to_seg_gat_agg():
    """G=1 with one unit per dst row in order IS the single-graph kernel."""
    from repro.kernels import seg_gat_agg_multigraph

    rng = np.random.default_rng(5)
    B, R, W, H, Dh, nblk = 8, 3, 2, 2, 8, 4
    ns = nblk * B
    col = _unique_cols(rng, R, W, nblk)
    masks = rng.random((R, W, B, B)) < 0.4
    ths = rng.standard_normal((ns, H)).astype(np.float32)
    thd = rng.standard_normal((R * B, H)).astype(np.float32)
    hs = rng.standard_normal((ns, H, Dh)).astype(np.float32)
    bias = rng.standard_normal((H,)).astype(np.float32)
    single = seg_gat_agg(
        jnp.asarray(col), jnp.asarray(masks), jnp.asarray(ths), jnp.asarray(thd),
        jnp.asarray(hs), edge_bias=jnp.asarray(bias), interpret=True,
    )
    multi = seg_gat_agg_multigraph(
        jnp.asarray(col), jnp.zeros((R,), jnp.int32), jnp.arange(R, dtype=jnp.int32),
        jnp.asarray(masks), jnp.asarray(ths)[None], jnp.asarray(thd)[None],
        jnp.asarray(hs), jnp.asarray(bias)[None], interpret=True,
    )
    np.testing.assert_allclose(np.asarray(multi), np.asarray(single), **TOL[jnp.float32])


def test_seg_gat_agg_multigraph_vjp_matches_block_autodiff():
    """The fused Pallas backward must agree with autodiff of the pure-jnp
    BLOCK oracle (stages.block_softmax_aggregate) for every input."""
    from repro.core.stages import block_softmax_aggregate
    from repro.kernels import seg_gat_agg_multigraph

    rng = np.random.default_rng(3)
    B, R, W, H, Dh, nblk = 8, 3, 2, 2, 8, 4
    ns = nblk * B
    col = _unique_cols(rng, R, W, nblk)
    masks = rng.random((R, W, B, B)) < 0.4
    ths = jnp.asarray(rng.standard_normal((ns, H)).astype(np.float32))
    thd = jnp.asarray(rng.standard_normal((R * B, H)).astype(np.float32))
    hs = jnp.asarray(rng.standard_normal((ns, H, Dh)).astype(np.float32))
    bias = jnp.asarray(rng.standard_normal((H,)).astype(np.float32))
    colj, masksj = jnp.asarray(col), jnp.asarray(masks)
    gid = jnp.zeros((R,), jnp.int32)
    row = jnp.arange(R, dtype=jnp.int32)

    def f_kernel(a, b, c, d):
        out = seg_gat_agg_multigraph(
            colj, gid, row, masksj, a[None], b[None], c, d[None], interpret=True
        )
        return jnp.sum(jnp.sin(out))

    def f_ref(a, b, c, d):
        out = block_softmax_aggregate(colj, masksj, a, b, c, edge_bias=d)
        return jnp.sum(jnp.sin(out))

    gk = jax.grad(f_kernel, argnums=(0, 1, 2, 3))(ths, thd, hs, bias)
    gr = jax.grad(f_ref, argnums=(0, 1, 2, 3))(ths, thd, hs, bias)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_seg_gat_agg_multigraph_matches_multilane_oracle():
    """The multi-lane kernel (§4.2 at Pallas level): mixed-graph work units
    in one launch must match the per-unit jnp online-softmax oracle."""
    from repro.core.multilane import _unit_na
    from repro.kernels import seg_gat_agg_multigraph

    rng = np.random.default_rng(7)
    B, U, W, G, H, Dh = 8, 4, 3, 3, 2, 8
    nblk = 4
    ns_pad = nblk * B
    col = np.full((U, W), -1, np.int32)
    for u in range(U):
        k = rng.integers(1, W + 1)
        col[u, :k] = rng.choice(nblk, size=k, replace=False)
    gid = rng.integers(0, G, U).astype(np.int32)
    row = rng.integers(0, nblk, U).astype(np.int32)
    masks = rng.random((U, W, B, B)) < 0.3
    ths = rng.standard_normal((G, ns_pad, H)).astype(np.float32)
    thd = rng.standard_normal((G, ns_pad, H)).astype(np.float32)
    hs = rng.standard_normal((ns_pad, H, Dh)).astype(np.float32)
    bias = rng.standard_normal((G, H)).astype(np.float32)
    out = seg_gat_agg_multigraph(
        jnp.asarray(col), jnp.asarray(gid), jnp.asarray(row), jnp.asarray(masks),
        jnp.asarray(ths), jnp.asarray(thd), jnp.asarray(hs), jnp.asarray(bias),
        interpret=True,
    )
    for u in range(U):
        ref = _unit_na(
            jnp.asarray(col[u]), jnp.asarray(masks[u]), jnp.int32(gid[u]),
            jnp.int32(row[u]), jnp.asarray(ths), jnp.asarray(thd), jnp.asarray(hs),
            jnp.asarray(bias), 0.2,
        )
        np.testing.assert_allclose(
            np.asarray(out[u * B : (u + 1) * B]), np.asarray(ref), rtol=3e-5, atol=3e-5
        )
