"""Test bootstrap: gate optional third-party deps.

The container this suite runs in does not always ship `hypothesis`; the
property tests only use a tiny slice of it (``given``/``settings`` +
integer/choice strategies), so a deterministic stand-in under
``tests/_compat`` fills in when the real package is absent.  When
hypothesis IS installed it wins — the stub directory is only added to
``sys.path`` after a failed lookup.
"""
import importlib.util
import os
import sys

if importlib.util.find_spec("hypothesis") is None:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_compat"))
