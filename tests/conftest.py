"""Test bootstrap: gate optional third-party deps, force a device mesh.

The container this suite runs in does not always ship `hypothesis`; the
property tests only use a tiny slice of it (``given``/``settings`` +
integer/choice strategies), so a deterministic stand-in under
``tests/_compat`` fills in when the real package is absent.  When
hypothesis IS installed it wins — the stub directory is only added to
``sys.path`` after a failed lookup.

The multilane/elastic-restart tests need REAL multi-device lane meshes,
so on CPU hosts the XLA host-platform device count is forced to 4 before
jax initializes (a no-op if the user already set XLA_FLAGS; conftest runs
before any test module imports jax).
"""
import importlib.util
import os
import sys

if importlib.util.find_spec("hypothesis") is None:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_compat"))

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=4"
    ).strip()
