"""Mesh-scale HGNN training launcher: convergence, fault injection,
elastic lane resharding.

The numerical contract (DESIGN.md §11, measured in tests/test_multilane):
checkpoint RESTORE is bit-identical for any lane count (leaves are
logical arrays), same-topology crash-resume replays bit-identically
(counter-based data state), and a trajectory continued on a different
lane count tracks the original to f32 tolerance (the lane partition
regroups the cross-unit gradient reduction).
"""
import jax
import numpy as np
import pytest

from repro.launch.hgnn_train import run_training

_SILENT = lambda *_: None

# tiny-but-real problem: every run here shares it (fixture-free so each
# test documents its own configuration)
_KW = dict(
    dataset="acm", model_name="HAN", hidden=8, heads=2, scale=0.05,
    block=16, max_edges=20_000, log=_SILENT, log_every=1,
)


def _leaves(state):
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(state)]


def test_han_loss_decreases_lane_sharded_kernel():
    """HAN trains with decreasing loss through the lane-sharded fused
    kernel path (the tentpole configuration, interpret twin on CPU)."""
    state, history, meta = run_training(steps=12, lanes=2, backend="kernel", **_KW)
    assert history[-1]["loss"] < history[0]["loss"]
    assert meta["plan_lanes"] == 2
    assert meta["backend"] in ("kernel", "kernel_interpret")


def test_rgat_loss_decreases():
    state, history, meta = run_training(
        steps=8, lanes=1, backend="kernel", **{**_KW, "model_name": "R-GAT"},
    )
    assert history[-1]["loss"] < history[0]["loss"]


def test_crash_at_step_k_resume_bit_identical(tmp_path):
    """Fault injection: crash at step k, relaunch, resume from the atomic
    checkpoint — final params bit-identical to an uninterrupted run."""
    kw = dict(steps=10, lanes=2, backend="kernel", ckpt_every=4, **_KW)

    ref_state, _, _ = run_training(ckpt_dir=str(tmp_path / "ref"), **kw)

    crashed = str(tmp_path / "crashed")
    with pytest.raises(RuntimeError, match="injected failure at step 7"):
        run_training(ckpt_dir=crashed, crash_at=7, **kw)
    resumed_state, history, _ = run_training(ckpt_dir=crashed, **kw)

    assert history[0]["step"] == 4  # resumed from the step-4 checkpoint
    for a, b in zip(_leaves(ref_state), _leaves(resumed_state)):
        np.testing.assert_array_equal(a, b)


def test_elastic_reshard_roundtrip_lane_mesh(tmp_path):
    """Checkpoint written on an L=2 lane mesh restores bit-identically
    onto L=4 and L=1 meshes (leaves are logical arrays; param_shardings
    re-derives placement from the same logical axes), and the continued
    trajectory tracks the L=2 one to f32 tolerance."""
    ckpt = str(tmp_path / "ckpt")
    kw = dict(backend="kernel", ckpt_every=3, **_KW)

    state2, _, _ = run_training(steps=6, lanes=2, ckpt_dir=ckpt, **kw)
    ref2 = _leaves(state2)

    # restore-only relaunches (steps already complete): any lane count
    for lanes in (4, 1):
        restored, _, _ = run_training(steps=6, lanes=lanes, ckpt_dir=ckpt, **kw)
        for a, b in zip(ref2, _leaves(restored)):
            np.testing.assert_array_equal(a, b)

    # continuation on the L=4 mesh vs uninterrupted L=2
    cont4, _, _ = run_training(steps=9, lanes=4, ckpt_dir=ckpt, **kw)
    ref9, _, _ = run_training(steps=9, lanes=2, ckpt_dir=str(tmp_path / "ref9"), **kw)
    for a, b in zip(_leaves(ref9), _leaves(cont4)):
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)
