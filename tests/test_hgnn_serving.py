"""HGNN serving engine + cross-request FP cache: lifecycle, capacity,
coherence, admission-policy wins, and the reuse-model regression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import NABackend, fp_buffer_traffic, stages
from repro.graphs import synthetic_hetgraph
from repro.serve import FPCache, GraphRequest, HGNNEngine, make_request_mix

MDM = ("movie", "director", "movie")
MAM = ("movie", "actor", "movie")
MKM = ("movie", "keyword", "movie")
CLUSTERS = [
    [MDM, ("movie", "director", "movie", "director", "movie")],
    [MAM, ("movie", "actor", "movie", "actor", "movie")],
    [MKM],
]
OUT_BYTES = 2 * 4 * 4  # heads * hidden * fp32


@pytest.fixture(scope="module")
def graph():
    return synthetic_hetgraph("imdb", scale=0.05, feat_scale=0.02, seed=0)


def _engine(graph, **kw):
    kw.setdefault("target_type", "movie")
    kw.setdefault("hidden", 4)
    kw.setdefault("heads", 2)
    kw.setdefault("num_slots", 2)
    kw.setdefault("cache_block_rows", 64)
    kw.setdefault("backend", NABackend.BLOCK)
    kw.setdefault("block", 8)
    kw.setdefault("max_edges", 2_000)
    kw.setdefault("seed", 0)
    return HGNNEngine(graph, **kw)


# -- FPCache unit ----------------------------------------------------------


def _xw(rng, n, din=3, dout=8):
    x = jnp.asarray(rng.standard_normal((n, din)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((din, dout)).astype(np.float32))
    return x, w, jnp.zeros((dout,))


def test_fp_cache_capacity_bound_and_hits():
    rng = np.random.default_rng(0)
    x, w, b = _xw(rng, 16)
    blk_bytes = 4 * 8 * 4  # block_rows * dout * fp32
    cache = FPCache(4 * blk_bytes, block_rows=4)

    out = cache.project("a", x, w, b)
    assert cache.stats.misses == 4 and cache.stats.hits == 0
    assert cache.resident_bytes == 4 * blk_bytes <= cache.capacity_bytes
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(stages.feature_projection(x, w, b)), rtol=1e-6
    )

    again = cache.project("a", x, w, b)
    assert cache.stats.hits == 4 and cache.stats.misses == 4
    assert np.array_equal(np.asarray(out), np.asarray(again))

    # uncached recomputation (capacity 0) is bit-identical to the cached path
    nocache = FPCache(0, block_rows=4)
    assert np.array_equal(np.asarray(nocache.project("a", x, w, b)), np.asarray(out))
    assert nocache.resident_bytes == 0 and nocache.num_blocks == 0

    # capacity smaller than the table: resident set stays bounded
    small = FPCache(2 * blk_bytes, block_rows=4)
    small.project("a", x, w, b)
    assert small.resident_bytes <= small.capacity_bytes
    assert small.num_blocks == 2


def test_fp_cache_version_invalidation():
    rng = np.random.default_rng(1)
    x, w, b = _xw(rng, 8)
    cache = FPCache(1 << 16, block_rows=4)
    old = cache.project("a", x, w, b)
    assert cache.version("a") == 0 and cache.num_blocks == 2

    cache.invalidate("a")
    assert cache.version("a") == 1
    assert cache.num_blocks == 0  # stale blocks dropped eagerly
    assert cache.stats.invalidations == 1

    x2 = x + 1.0
    new = cache.project("a", x2, w, b)
    assert cache.stats.hits == 0  # old-version keys can never be served
    np.testing.assert_allclose(
        np.asarray(new), np.asarray(stages.feature_projection(x2, w, b)), rtol=1e-6
    )
    assert not np.array_equal(np.asarray(new), np.asarray(old))


def test_fp_cache_similarity_eviction_prefers_demanded_types():
    rng = np.random.default_rng(2)
    xa, w, b = _xw(rng, 4)
    xb, _, _ = _xw(rng, 4)
    xc, _, _ = _xw(rng, 4)
    blk_bytes = 4 * 8 * 4

    # LRU baseline: oldest block ("a") is the victim
    lru = FPCache(2 * blk_bytes, block_rows=4, policy="lru")
    lru.project("a", xa, w, b)
    lru.project("b", xb, w, b)
    lru.project("c", xc, w, b)
    assert lru.resident_types() == {"b", "c"}

    # similarity-weighted: "b" has zero queue demand -> evicted despite
    # being more recently used than "a"
    sim = FPCache(2 * blk_bytes, block_rows=4, policy="similarity")
    sim.project("a", xa, w, b)
    sim.project("b", xb, w, b)
    sim.set_demand({"a": 10.0, "b": 0.0, "c": 1.0})
    sim.project("c", xc, w, b)
    assert sim.resident_types() == {"a", "c"}


# -- engine lifecycle ------------------------------------------------------


def test_engine_request_lifecycle_and_slot_reuse(graph):
    eng = _engine(graph, cache_bytes=1 << 20, admission="fifo")
    r0 = GraphRequest(rid=0, metapaths=[MDM, MAM])  # 2 steps of work
    r1 = GraphRequest(rid=1, metapaths=[MKM])
    r2 = GraphRequest(rid=2, metapaths=[MKM])
    for r in (r0, r1, r2):
        eng.submit(r)
        assert r.submitted_step == 0

    # step 0: two slots -> r0 and r1 admitted in FIFO order, r2 waits
    assert eng.step() == 2
    assert r0.admitted_step == 0 and r1.admitted_step == 0
    assert r2.admitted_step == -1
    assert r1.done and r1.finished_step == 0
    assert not r0.done  # one metapath of two executed

    # step 1: r2 reuses the slot r1 freed
    assert eng.step() == 2
    assert r2.admitted_step == 1 and r2.finished_step == 1
    assert r0.finished_step == 1

    assert eng.step() == 0  # drained
    assert not eng.queue and all(s is None for s in eng.slots)
    assert {r.rid for r in eng.finished} == {0, 1, 2}
    for r in (r0, r1, r2):
        assert 0 <= r.submitted_step <= r.admitted_step <= r.finished_step
        assert r.result.shape == (eng.n_target, eng.heads * eng.hidden)
        assert r.beta.shape == (len(r.metapaths),)
        np.testing.assert_allclose(float(jnp.sum(r.beta)), 1.0, rtol=1e-5)

    m = eng.metrics()
    assert m["requests_finished"] == 3 and m["requests_waiting"] == 0
    assert m["na_launches"] == 2  # one fused launch per non-empty step
    assert eng.traffic().total == m["reused_bytes"] + m["fetched_bytes"]


def test_engine_rejects_non_target_endpoints(graph):
    eng = _engine(graph, cache_bytes=0)
    with pytest.raises(AssertionError):
        eng.submit(GraphRequest(rid=0, metapaths=[("director", "movie", "director")]))


def test_cached_results_bitwise_identical_to_uncached(graph):
    reqs = lambda: make_request_mix(0, CLUSTERS, repeats=2)
    ref_eng = _engine(graph, cache_bytes=0, admission="fifo")
    for r in reqs():
        ref_eng.submit(r)
    ref = {r.rid: np.asarray(r.result) for r in ref_eng.run()}
    assert ref_eng.metrics()["cache_hit_rate"] == 0.0

    for admission in ("fifo", "similarity"):
        eng = _engine(graph, cache_bytes=1 << 20, admission=admission)
        for r in reqs():
            eng.submit(r)
        got = {r.rid: np.asarray(r.result) for r in eng.run()}
        assert got.keys() == ref.keys()
        for rid in ref:
            assert np.array_equal(got[rid], ref[rid]), (admission, rid)
    assert eng.metrics()["cache_hit_rate"] > 0.0  # the cache actually engaged


def test_similarity_admission_beats_fifo_hit_rate(graph):
    table = {t: n * OUT_BYTES for t, n in graph.vertex_counts.items()}
    cap = table["movie"] + max(table.values()) + 64 * OUT_BYTES  # adversarial

    metrics = {}
    for admission in ("fifo", "similarity"):
        eng = _engine(graph, cache_bytes=cap, admission=admission)
        for r in make_request_mix(0, CLUSTERS, repeats=3):
            eng.submit(r)
        eng.run()
        metrics[admission] = eng.metrics()
    fifo, sim = metrics["fifo"], metrics["similarity"]
    assert fifo["requests_finished"] == sim["requests_finished"] == 9
    assert sim["cache_hit_rate"] > fifo["cache_hit_rate"]  # strictly better
    assert sim["fp_rows_computed"] < fifo["fp_rows_computed"]
    assert sim["reused_bytes"] > fifo["reused_bytes"]


def test_update_features_coherence(graph):
    run_one = lambda eng: (eng.submit(GraphRequest(rid=0, metapaths=[MDM])), eng.run())[1][-1]

    eng = _engine(graph, cache_bytes=1 << 20)
    stale = np.asarray(run_one(eng).result)

    rng = np.random.default_rng(7)
    new_x = rng.standard_normal(
        (graph.num_vertices("movie"), graph.feature_dim("movie"))
    ).astype(np.float32)
    eng.update_features("movie", new_x)
    assert eng.cache.stats.invalidations == 1
    eng.finished.clear()
    fresh = np.asarray(run_one(eng).result)
    assert not np.array_equal(fresh, stale)  # stale projections not served

    # matches an engine that never saw the old features (bitwise)
    eng2 = _engine(graph, cache_bytes=1 << 20)
    eng2.update_features("movie", new_x)
    assert np.array_equal(np.asarray(run_one(eng2).result), fresh)


# -- reuse model regression ------------------------------------------------


class _SG:
    def __init__(self, *path_types):
        self.path_types = path_types


def test_fp_buffer_traffic_partial_block_regression():
    """Pins the partial-residency byte counts: a table larger than the
    whole buffer keeps a resident prefix that is reused on re-access,
    instead of charging a full miss (matches serve/fp_cache.py)."""
    counts = {"a": 10, "b": 20, "c": 30}
    bpv = {"a": 4, "b": 4, "c": 4}  # tables: 40 / 80 / 120 bytes
    sgs = [_SG("a", "b"), _SG("b", "c"), _SG("c", "a")]

    # buffer (100) < table c (120): c keeps a 100-byte resident prefix,
    # re-accessed in g2 -> 100 reused + only 20 re-fetched
    t = fp_buffer_traffic([0, 1, 2], sgs, counts, bytes_per_vertex=bpv, fpbuf_bytes=100)
    assert (t.reused_bytes, t.fetched_bytes) == (180, 300)
    assert t.total == 480  # = bytes touched, independent of buffer size

    # everything fits: only first touches fetch
    t = fp_buffer_traffic([0, 1, 2], sgs, counts, bytes_per_vertex=bpv, fpbuf_bytes=1000)
    assert (t.reused_bytes, t.fetched_bytes) == (240, 240)

    # zero-capacity buffer: every access is a full fetch
    t = fp_buffer_traffic([0, 1, 2], sgs, counts, bytes_per_vertex=bpv, fpbuf_bytes=0)
    assert (t.reused_bytes, t.fetched_bytes) == (0, 480)
