"""Minimal deterministic stand-in for the `hypothesis` API this suite uses.

Only loaded (via tests/conftest.py) when the real package is missing.
``@given`` runs the test body ``max_examples`` times with values drawn
from a seeded PRNG — deterministic across runs, no shrinking, no
database.  Supported surface: ``given``, ``settings``, ``strategies.
{data,integers,sampled_from,booleans,floats,lists,tuples,just}``.
"""
from __future__ import annotations

import random

from . import strategies

__all__ = ["given", "settings", "strategies"]

_DEFAULT_MAX_EXAMPLES = 20


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco


def given(*strats):
    def deco(fn):
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_max_examples", None)
            if n is None:
                n = getattr(fn, "_max_examples", _DEFAULT_MAX_EXAMPLES)
            for i in range(n):
                rnd = random.Random(0x5EED + 7919 * i)
                drawn = [s.example(rnd) for s in strats]
                fn(*args, *drawn, **kwargs)

        # No functools.wraps: a __wrapped__ attribute would expose the
        # original signature and make pytest treat the given-supplied
        # parameters as fixtures.
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper._max_examples = getattr(fn, "_max_examples", None)
        return wrapper

    return deco
