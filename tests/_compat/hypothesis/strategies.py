"""Strategy objects for the hypothesis stand-in (see package docstring)."""
from __future__ import annotations

import random
from typing import Any, Callable, Sequence


class SearchStrategy:
    def __init__(self, draw_fn: Callable[[random.Random], Any]):
        self._draw = draw_fn

    def example(self, rnd: random.Random) -> Any:
        return self._draw(rnd)

    def map(self, f: Callable[[Any], Any]) -> "SearchStrategy":
        return SearchStrategy(lambda rnd: f(self._draw(rnd)))

    def filter(self, pred: Callable[[Any], bool]) -> "SearchStrategy":
        def draw(rnd: random.Random):
            for _ in range(1000):
                v = self._draw(rnd)
                if pred(v):
                    return v
            raise ValueError("filter predicate never satisfied")

        return SearchStrategy(draw)


class _DataObject:
    """Interactive draws: ``data.draw(strategy)`` inside the test body."""

    def __init__(self, rnd: random.Random):
        self._rnd = rnd

    def draw(self, strategy: SearchStrategy, label: str | None = None):
        return strategy.example(self._rnd)


def data() -> SearchStrategy:
    return SearchStrategy(lambda rnd: _DataObject(rnd))


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(lambda rnd: rnd.randint(min_value, max_value))


def sampled_from(elements: Sequence) -> SearchStrategy:
    xs = list(elements)
    return SearchStrategy(lambda rnd: xs[rnd.randrange(len(xs))])


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rnd: rnd.random() < 0.5)


def floats(min_value: float = 0.0, max_value: float = 1.0, **_kw) -> SearchStrategy:
    return SearchStrategy(lambda rnd: rnd.uniform(min_value, max_value))


def just(value) -> SearchStrategy:
    return SearchStrategy(lambda rnd: value)


def lists(elements: SearchStrategy, *, min_size: int = 0, max_size: int = 10) -> SearchStrategy:
    def draw(rnd: random.Random):
        n = rnd.randint(min_size, max_size)
        return [elements.example(rnd) for _ in range(n)]

    return SearchStrategy(draw)


def tuples(*strats: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(lambda rnd: tuple(s.example(rnd) for s in strats))
