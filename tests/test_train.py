"""Training substrate: convergence, fault tolerance, checkpoint semantics,
elastic re-shard."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint, reshard_to
from repro.configs import smoke_config
from repro.data import SyntheticLMData
from repro.models.lm.api import build
from repro.optim import AdamWConfig
from repro.train import make_train_step, train_loop
from repro.train.step import init_train_state


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config("llama3.2-3b")
    api = build(cfg)
    opt = AdamWConfig(lr=1e-2, weight_decay=0.0)
    step = make_train_step(api, opt, lr_schedule=lambda s: jnp.asarray(1e-2))
    return cfg, api, opt, step


def _data(cfg):
    return SyntheticLMData(vocab_size=cfg.vocab_size, seq_len=16, global_batch=16, seed=7)


def test_loss_decreases(setup):
    cfg, api, opt, step = setup
    state = init_train_state(api, jax.random.key(0), opt)
    state, hist = train_loop(
        state=state, train_step=step, data=_data(cfg), steps=50, log_every=10,
        log=lambda s: None,
    )
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.85, [h["loss"] for h in hist]


def test_crash_resume_bit_identical(setup):
    cfg, api, opt, step = setup
    with tempfile.TemporaryDirectory() as d:
        # uninterrupted run
        s0 = init_train_state(api, jax.random.key(0), opt)
        ref, _ = train_loop(
            state=s0, train_step=step, data=_data(cfg), steps=25,
            ckpt_dir=os.path.join(d, "a"), ckpt_every=10, log=lambda s: None,
        )
        # crashed run + resume
        s1 = init_train_state(api, jax.random.key(0), opt)
        with pytest.raises(RuntimeError, match="injected failure"):
            train_loop(
                state=s1, train_step=step, data=_data(cfg), steps=25,
                ckpt_dir=os.path.join(d, "b"), ckpt_every=10, crash_at=17,
                log=lambda s: None,
            )
        s2 = init_train_state(api, jax.random.key(0), opt)
        resumed, _ = train_loop(
            state=s2, train_step=step, data=_data(cfg), steps=25,
            ckpt_dir=os.path.join(d, "b"), ckpt_every=10, resume=True,
            log=lambda s: None,
        )
        for x, y in zip(
            jax.tree_util.tree_leaves(ref.params), jax.tree_util.tree_leaves(resumed.params)
        ):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_atomicity(setup):
    """A leftover .tmp dir from a crashed write must not be picked up."""
    cfg, api, opt, step = setup
    state = init_train_state(api, jax.random.key(0), opt)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 10, state, aux={"data": {"step": 10, "seed": 7}})
        os.makedirs(os.path.join(d, "step_20.tmp"))  # simulated torn write
        assert latest_step(d) == 10
        restored, aux = restore_checkpoint(d, 10, state)
        assert aux["data"]["step"] == 10
        for x, y in zip(jax.tree_util.tree_leaves(state), jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_elastic_reshard_roundtrip(setup):
    """Checkpoints restore onto a different mesh layout (elastic restart)."""
    from repro.launch.mesh import make_mesh
    from jax.sharding import NamedSharding, PartitionSpec

    cfg, api, opt, step = setup
    state = init_train_state(api, jax.random.key(0), opt)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, state, aux={})
        restored, _ = restore_checkpoint(d, 1, state)
        mesh = make_mesh((1, 1), ("data", "model"))  # "new" degenerate mesh
        shardings = jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh, PartitionSpec()), restored
        )
        placed = reshard_to(restored, shardings)
        for x, y in zip(jax.tree_util.tree_leaves(state), jax.tree_util.tree_leaves(placed)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_microbatch_accumulation_matches_full_batch(setup):
    """Grad accumulation must be arithmetically equivalent to one batch."""
    cfg, api, opt, _ = setup
    sched = lambda s: jnp.asarray(1e-2)
    step1 = jax.jit(make_train_step(api, opt, microbatches=1, lr_schedule=sched))
    step4 = jax.jit(make_train_step(api, opt, microbatches=4, lr_schedule=sched))
    data = _data(cfg)
    batch = data.next()
    s0 = init_train_state(api, jax.random.key(0), opt)
    a, ma = step1(s0, batch)
    s0b = init_train_state(api, jax.random.key(0), opt)
    b, mb = step4(s0b, batch)
    np.testing.assert_allclose(float(ma["loss"]), float(mb["loss"]), rtol=1e-5)
    for x, y in zip(jax.tree_util.tree_leaves(a.params), jax.tree_util.tree_leaves(b.params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=5e-4, atol=5e-5)
