"""Observability layer (obs/, DESIGN.md §12): tracer, metrics, emitter,
benchmark stats, and the serving engine's registry wiring."""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fusion import NABackend
from repro.graphs import dataset_target, synthetic_hetgraph
from repro.obs import (
    Emitter,
    MetricsRegistry,
    disable_tracing,
    enable_tracing,
    get_tracer,
    trace_span,
    tracing_enabled,
)
from repro.serve.hgnn_engine import HGNNEngine, make_request_mix


@pytest.fixture(autouse=True)
def _clean_tracer():
    disable_tracing()
    yield
    disable_tracing()


# -- tracer ------------------------------------------------------------------


def test_disabled_tracer_is_noop_identity():
    assert not tracing_enabled()
    x = jnp.arange(6.0).reshape(2, 3)

    def f(a):
        return a * 2.0 + 1.0

    traced_f = trace_span("t/f", stage="NA")(f)
    with trace_span("t/outer", k=1) as sp:
        y = sp.sync(f(x))
        sp.annotate(extra=2)  # no-op span absorbs annotations
    # bit-identical outputs through the decorator fast path
    assert np.array_equal(np.asarray(traced_f(x)), np.asarray(f(x)))
    assert np.array_equal(np.asarray(y), np.asarray(f(x)))
    assert get_tracer() is None


def test_span_nesting_and_attributes_deterministic():
    def program():
        with trace_span("outer", stage="NA", lane="sg/APA", edges=7):
            with trace_span("inner", stage="FP"):
                pass
            with trace_span("inner2", lane="slot0"):
                pass

    shapes = []
    for _ in range(2):
        tracer = enable_tracing()
        program()
        shapes.append(
            [
                (e["name"], e["depth"], e["parent"], e["lane"], e["attrs"])
                for e in sorted(tracer.spans(), key=lambda e: e["name"])
            ]
        )
        disable_tracing()
    assert shapes[0] == shapes[1]  # structure independent of timing
    by_name = {e[0]: e for e in shapes[0]}
    assert by_name["outer"] == ("outer", 0, None, "sg/APA", {"stage": "NA", "edges": 7})
    assert by_name["inner"][1:4] == (1, "outer", "sg/APA")  # lane inherited
    assert by_name["inner2"][3] == "slot0"  # explicit lane wins


def test_chrome_trace_export_valid(tmp_path):
    tracer = enable_tracing()
    with trace_span("na/APA", stage="NA", lane="sg/APA", edges=3):
        pass
    with trace_span("na/APCPA", stage="NA", lane="sg/APCPA"):
        pass
    path = tmp_path / "trace.json"
    tracer.export_chrome_trace(str(path))
    doc = json.loads(path.read_text())
    events = doc["traceEvents"]
    xs = [e for e in events if e["ph"] == "X"]
    metas = [e for e in events if e["ph"] == "M"]
    assert len(xs) == 2
    for e in xs:
        assert {"name", "ph", "pid", "tid", "ts", "dur", "cat", "args"} <= set(e)
        assert e["dur"] >= 0 and e["cat"] == "NA"
    # one thread_name row per lane, distinct tids per semantic graph
    lanes = {e["args"]["name"]: e["tid"] for e in metas if e["name"] == "thread_name"}
    assert set(lanes) == {"sg/APA", "sg/APCPA"}
    assert len(set(lanes.values())) == 2
    tids = {e["name"]: e["tid"] for e in xs}
    assert tids["na/APA"] == lanes["sg/APA"]
    assert tids["na/APCPA"] == lanes["sg/APCPA"]


def test_jsonl_export(tmp_path):
    tracer = enable_tracing()
    with trace_span("a", stage="FP"):
        pass
    path = tmp_path / "spans.jsonl"
    tracer.export_jsonl(str(path))
    lines = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert [ln["name"] for ln in lines] == ["a"]
    assert lines[0]["attrs"] == {"stage": "FP"}


# -- metrics -----------------------------------------------------------------


def test_histogram_bucket_edges_and_percentiles():
    reg = MetricsRegistry()
    h = reg.histogram("t.lat", base=2.0)
    for v in (1.0, 1.5, 4.0):
        h.observe(v)
    h.observe(0.0)  # underflow
    assert h.bucket_edges() == [(1.0, 1), (2.0, 1), (4.0, 1)]
    assert h.underflow == 1
    # conservative (upper-edge) percentiles
    assert h.percentile(0.5) == 1.0
    assert h.percentile(1.0) == 4.0
    snap = h.snapshot()
    assert snap["count"] == 4 and snap["max"] == 4.0 and snap["min"] == 0.0


def test_labeled_series_and_kind_collision():
    reg = MetricsRegistry()
    reg.counter("req", route="a").inc(2)
    reg.counter("req", route="b").inc(3)
    assert reg.counter("req", route="a") is reg.counter("req", route="a")
    assert reg.value("req", route="a") == 2
    assert reg.value("req", route="b") == 3
    with pytest.raises(TypeError):
        reg.gauge("req", route="a")  # same series, different kind
    snap = reg.snapshot()
    assert {s["labels"]["route"] for s in snap["counters"]["req"]} == {"a", "b"}


def test_registry_export_json(tmp_path):
    reg = MetricsRegistry()
    reg.gauge("g").set(1.5)
    reg.histogram("h").observe(3.0)
    path = tmp_path / "metrics.json"
    reg.export_json(str(path))
    doc = json.loads(path.read_text())
    assert doc["gauges"]["g"][0]["value"] == 1.5
    assert doc["histograms"]["h"][0]["value"]["count"] == 1


def test_emitter_line_and_jsonl(tmp_path):
    got = []
    path = tmp_path / "ev.jsonl"
    em = Emitter(sink=got.append, jsonl_path=str(path))
    line = em.emit("train", step=3, loss=0.123456789, tags=["a", "b"])
    em.close()
    assert line == "[train] step=3 loss=0.123457 tags=a/b" == got[0]
    rec = json.loads(path.read_text())
    assert rec == {"event": "train", "step": 3, "loss": 0.123456789, "tags": ["a", "b"]}


# -- serving engine wiring ---------------------------------------------------


def test_engine_registry_matches_metrics():
    g = synthetic_hetgraph("imdb", scale=0.05, feat_scale=0.02, seed=0)
    target, _ = dataset_target("imdb")
    eng = HGNNEngine(
        g, target_type=target, num_slots=2, cache_bytes=1 << 18,
        backend=NABackend.BLOCK,
    )
    clusters = [
        [("movie", "director", "movie"), ("movie", "actor", "movie")],
        [("movie", "keyword", "movie")],
    ]
    for req in make_request_mix(0, clusters, repeats=2):
        eng.submit(req)
    eng.run()
    m = eng.metrics()
    assert m["requests_finished"] == 4
    for k, v in m.items():
        assert abs(eng.registry.value(f"serve.{k}") - float(v)) < 1e-9, k
    # per-step latency histogram saw every step
    snap = eng.registry.snapshot()
    assert snap["histograms"]["serve.step_ms"][0]["value"]["count"] == m["steps"]
    # analytical FP-traffic replay is self-consistent on this run
    drift = eng.fp_model_drift()
    assert drift["fp_measured_fetched_bytes"] == m["fetched_bytes"]
    assert 0.0 < m["fp_model_drift"] <= 1.5


def test_engine_spans_under_tracing():
    g = synthetic_hetgraph("imdb", scale=0.05, feat_scale=0.02, seed=0)
    target, _ = dataset_target("imdb")
    eng = HGNNEngine(
        g, target_type=target, num_slots=2, backend=NABackend.BLOCK,
    )
    for req in make_request_mix(0, [[("movie", "director", "movie")]], repeats=2):
        eng.submit(req)
    tracer = enable_tracing(sync=True)
    eng.run()
    names = set(tracer.span_names())
    assert {"serve/step", "serve/fp", "serve/theta", "serve/na"} <= names
    assert any(n.startswith("serve/fa/slot") for n in names)
    # per-graph NA spans from the fallback loop ride their own sg/ lanes
    na = [e for e in tracer.spans() if e["name"].startswith("na/")]
    assert na and all(e["lane"].startswith("sg/") for e in na)


# -- benchmark stats ---------------------------------------------------------


def test_timeit_stats_shape_and_median():
    from benchmarks.common import timeit, timeit_stats

    calls = []

    def fn():
        calls.append(1)
        return ()

    p10, p50, p90, iters = timeit_stats(fn, warmup=1, iters=5)
    assert iters == 5 and len(calls) == 6
    assert 0.0 <= p10 <= p50 <= p90
    assert timeit(fn, warmup=0, iters=3) >= 0.0


def test_run_py_duplicate_registration_fails():
    from benchmarks import run as bench_run

    benches = bench_run._registry()
    assert "obs_overhead" in benches and len(benches) >= 12
    # the registry guard itself
    ns: dict = {}

    def register(name, fn, benches=ns):
        if name in benches:
            raise SystemExit(f"duplicate benchmark registration: {name!r}")
        benches[name] = fn

    register("x", lambda r: None)
    with pytest.raises(SystemExit, match="duplicate"):
        register("x", lambda r: None)
