"""Graph substrate: SGB composition oracle, formats, datasets."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.graphs import (
    TABLE5,
    build_semantic_graphs,
    block_csr_to_dense,
    dataset_metapaths,
    dense_adjacency,
    make_relation,
    relation_semantic_graphs,
    synthetic_hetgraph,
    to_block_csr,
    to_padded_edges,
)
from repro.graphs.hetgraph import HetGraph
from repro.graphs.sgb import _compose


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_compose_matches_dense_boolean_matmul(data):
    n_a = data.draw(st.integers(2, 12))
    n_b = data.draw(st.integers(2, 12))
    n_c = data.draw(st.integers(2, 12))
    e1 = data.draw(st.integers(0, 30))
    e2 = data.draw(st.integers(0, 30))
    rng = np.random.default_rng(data.draw(st.integers(0, 999)))
    src_a = rng.integers(0, n_a, e1).astype(np.int32)
    mid_a = rng.integers(0, n_b, e1).astype(np.int32)
    mid_b = rng.integers(0, n_b, e2).astype(np.int32)
    dst_b = rng.integers(0, n_c, e2).astype(np.int32)
    s, d = _compose(src_a, mid_a, mid_b, dst_b)
    got = np.zeros((n_a, n_c), bool)
    if s.size:
        got[s, d] = True
    A = np.zeros((n_a, n_b), bool)
    B = np.zeros((n_b, n_c), bool)
    A[src_a, mid_a] = True
    B[mid_b, dst_b] = True
    np.testing.assert_array_equal(got, A @ B)


@pytest.mark.parametrize("name", ["imdb", "acm", "dblp"])
def test_synthetic_datasets_match_table5_structure(name):
    g = synthetic_hetgraph(name, scale=1.0, feat_scale=0.05, seed=0)
    spec = TABLE5[name]
    for t, n in spec["vertices"].items():
        assert g.num_vertices(t) == n
    for rname, (st_, dt, ne) in spec["relations"].items():
        rel = g.relations[rname]
        assert rel.src_type == st_ and rel.dst_type == dt
        assert rel.num_edges >= 0.8 * min(ne, g.num_vertices(st_) * g.num_vertices(dt))
    sgs = relation_semantic_graphs(g)
    assert len(sgs) == len(spec["relations"])


def test_block_csr_roundtrip_and_padded_edges():
    g = synthetic_hetgraph("dblp", scale=0.05, feat_scale=0.1, seed=1)
    sgs = build_semantic_graphs(g, dataset_metapaths("dblp"), max_edges=5000)
    for sg in sgs:
        bc = to_block_csr(sg, block=16)
        dense = dense_adjacency(sg)
        padded = np.zeros((bc.num_dst_pad, bc.num_src_pad), bool)
        padded[: dense.shape[0], : dense.shape[1]] = dense
        np.testing.assert_array_equal(block_csr_to_dense(bc), padded)
        pe = to_padded_edges(sg)
        assert pe.num_edges == sg.num_edges
        assert np.all(np.diff(pe.dst[pe.valid]) >= 0)  # dst-sorted


def test_empty_semantic_graph_formats():
    g = HetGraph(
        vertex_counts={"a": 5, "b": 4},
        features={"a": np.zeros((5, 3), np.float32), "b": np.zeros((4, 3), np.float32)},
        relations={"AB": make_relation("AB", "a", "b", [], [])},
    )
    sg = relation_semantic_graphs(g)[0]
    bc = to_block_csr(sg, block=4)
    assert bc.num_edges == 0
    assert (bc.col_index == -1).all()
    pe = to_padded_edges(sg)
    assert pe.num_edges == 0
