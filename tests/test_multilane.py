"""Independency-aware parallel execution: multilane NA correctness +
workload balancing effect."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import NABackend, batch_semantic_graph, neighbor_aggregate
from repro.core.multilane import build_multilane_plan, multilane_na
from repro.graphs import build_semantic_graphs, dataset_metapaths, synthetic_hetgraph


@pytest.fixture(scope="module")
def dblp_setup():
    rng = np.random.default_rng(0)
    g = synthetic_hetgraph("dblp", scale=0.05, feat_scale=0.1)
    sgs = build_semantic_graphs(g, dataset_metapaths("dblp"))
    B, H, Dh = 16, 2, 8
    batches = [batch_semantic_graph(s, block=B) for s in sgs]
    G = len(batches)
    ns = batches[0].num_src
    ns_pad = ((ns + B - 1) // B) * B
    nd_pad = batches[0].num_dst_pad
    hs = np.zeros((ns_pad, H, Dh), np.float32)
    hs[:ns] = rng.standard_normal((ns, H, Dh))
    ths = np.zeros((G, ns_pad, H), np.float32)
    thd = np.zeros((G, nd_pad, H), np.float32)
    for i in range(G):
        ths[i, :ns] = rng.standard_normal((ns, H))
        thd[i, :ns] = rng.standard_normal((ns, H))
    return batches, jnp.asarray(ths), jnp.asarray(thd), jnp.asarray(hs)


@pytest.mark.parametrize("lanes", [1, 2, 4, 8])
def test_multilane_matches_reference_any_lane_count(dblp_setup, lanes):
    batches, ths, thd, hs = dblp_setup
    plan = build_multilane_plan(batches, lanes)
    z = multilane_na(plan, ths, thd, hs)
    for i, b in enumerate(batches):
        ref = neighbor_aggregate(
            b, ths[i, : b.num_src], thd[i, : b.num_dst], hs[: b.num_src],
            backend=NABackend.SEGMENT,
        )
        np.testing.assert_allclose(
            np.asarray(z[i, : b.num_dst]), np.asarray(ref), rtol=5e-5, atol=5e-5
        )


@pytest.mark.parametrize("lanes", [1, 4])
def test_multilane_kernel_backend_matches_reference(dblp_setup, lanes):
    """backend="kernel_interpret" (one fused Pallas launch for all lanes'
    units) must match the vmap reference on the same plan."""
    batches, ths, thd, hs = dblp_setup
    plan = build_multilane_plan(batches, lanes)
    ref = multilane_na(plan, ths, thd, hs)
    ker = multilane_na(plan, ths, thd, hs, backend="kernel_interpret")
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref), atol=1e-5)


def test_multilane_backend_rejects_unknown():
    with pytest.raises(ValueError, match="backend"):
        multilane_na(None, None, None, None, backend="nope")


def test_balanced_beats_naive_on_skewed_workload(dblp_setup):
    batches, *_ = dblp_setup
    plan_b = build_multilane_plan(batches, 4, balanced=True)
    plan_n = build_multilane_plan(batches, 4, balanced=False)
    assert plan_b.lane_plan.imbalance() <= plan_n.lane_plan.imbalance()
    # critical path (max lane load) strictly better on DBLP's skewed graphs
    assert plan_b.lane_plan.lane_load.max() < plan_n.lane_plan.lane_load.max()


def test_multilane_unbalanced_still_correct(dblp_setup):
    batches, ths, thd, hs = dblp_setup
    plan = build_multilane_plan(batches, 4, balanced=False)
    z = multilane_na(plan, ths, thd, hs)
    for i, b in enumerate(batches):
        ref = neighbor_aggregate(
            b, ths[i, : b.num_src], thd[i, : b.num_dst], hs[: b.num_src],
            backend=NABackend.SEGMENT,
        )
        np.testing.assert_allclose(
            np.asarray(z[i, : b.num_dst]), np.asarray(ref), rtol=5e-5, atol=5e-5
        )
