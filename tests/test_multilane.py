"""Independency-aware parallel execution: multilane NA correctness +
workload balancing effect + the training equivalence contract.

The differential tests pin the contract DESIGN.md §11 documents: for a
jitted HAN train step the LOSS is bit-identical across NA backends
(BLOCK / MULTIGRAPH / MULTIGRAPH_INTERPRET) and across lane counts
L∈{1,2,4} under shard_map; gradients are bit-deterministic per topology
and agree across topologies/backends to f32 tolerance (measured ~1e-9 —
the lane partition regroups the cross-unit d_h_src reduction).

The property tests fuzz the plan builders and the multigraph VJP over
random unit tables and degenerate shapes (empty graph, single edge,
all-padded block) — degenerate rows must produce exact zeros, never NaN.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import NABackend, batch_semantic_graph, cpu_fallback, neighbor_aggregate
from repro.core.fusion import build_unit_tables
from repro.core.multilane import build_multilane_plan, multilane_na
from repro.graphs import build_semantic_graphs, dataset_metapaths, synthetic_hetgraph
from repro.graphs.hetgraph import SemanticGraph
from repro.launch.hgnn_train import build_problem
from repro.launch.mesh import make_lane_mesh
from repro.models.hgnn import han_forward_multilane
from repro.models.hgnn.han import han_forward, init_han


@pytest.fixture(scope="module")
def dblp_setup():
    rng = np.random.default_rng(0)
    g = synthetic_hetgraph("dblp", scale=0.05, feat_scale=0.1)
    sgs = build_semantic_graphs(g, dataset_metapaths("dblp"))
    B, H, Dh = 16, 2, 8
    batches = [batch_semantic_graph(s, block=B) for s in sgs]
    G = len(batches)
    ns = batches[0].num_src
    ns_pad = ((ns + B - 1) // B) * B
    nd_pad = batches[0].num_dst_pad
    hs = np.zeros((ns_pad, H, Dh), np.float32)
    hs[:ns] = rng.standard_normal((ns, H, Dh))
    ths = np.zeros((G, ns_pad, H), np.float32)
    thd = np.zeros((G, nd_pad, H), np.float32)
    for i in range(G):
        ths[i, :ns] = rng.standard_normal((ns, H))
        thd[i, :ns] = rng.standard_normal((ns, H))
    return batches, jnp.asarray(ths), jnp.asarray(thd), jnp.asarray(hs)


@pytest.mark.parametrize("lanes", [1, 2, 4, 8])
def test_multilane_matches_reference_any_lane_count(dblp_setup, lanes):
    batches, ths, thd, hs = dblp_setup
    plan = build_multilane_plan(batches, lanes)
    z = multilane_na(plan, ths, thd, hs)
    for i, b in enumerate(batches):
        ref = neighbor_aggregate(
            b, ths[i, : b.num_src], thd[i, : b.num_dst], hs[: b.num_src],
            backend=NABackend.SEGMENT,
        )
        np.testing.assert_allclose(
            np.asarray(z[i, : b.num_dst]), np.asarray(ref), rtol=5e-5, atol=5e-5
        )


@pytest.mark.parametrize("lanes", [1, 4])
def test_multilane_kernel_backend_matches_reference(dblp_setup, lanes):
    """backend="kernel_interpret" (one fused Pallas launch for all lanes'
    units) must match the vmap reference on the same plan."""
    batches, ths, thd, hs = dblp_setup
    plan = build_multilane_plan(batches, lanes)
    ref = multilane_na(plan, ths, thd, hs)
    ker = multilane_na(plan, ths, thd, hs, backend="kernel_interpret")
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref), atol=1e-5)


def test_multilane_backend_rejects_unknown():
    with pytest.raises(ValueError, match="backend"):
        multilane_na(None, None, None, None, backend="nope")


def test_balanced_beats_naive_on_skewed_workload(dblp_setup):
    batches, *_ = dblp_setup
    plan_b = build_multilane_plan(batches, 4, balanced=True)
    plan_n = build_multilane_plan(batches, 4, balanced=False)
    assert plan_b.lane_plan.imbalance() <= plan_n.lane_plan.imbalance()
    # critical path (max lane load) strictly better on DBLP's skewed graphs
    assert plan_b.lane_plan.lane_load.max() < plan_n.lane_plan.lane_load.max()


def test_multilane_unbalanced_still_correct(dblp_setup):
    batches, ths, thd, hs = dblp_setup
    plan = build_multilane_plan(batches, 4, balanced=False)
    z = multilane_na(plan, ths, thd, hs)
    for i, b in enumerate(batches):
        ref = neighbor_aggregate(
            b, ths[i, : b.num_src], thd[i, : b.num_dst], hs[: b.num_src],
            backend=NABackend.SEGMENT,
        )
        np.testing.assert_allclose(
            np.asarray(z[i, : b.num_dst]), np.asarray(ref), rtol=5e-5, atol=5e-5
        )


# --- differential tests: the training equivalence contract -----------------

GRAD_ATOL = 1e-8  # measured max |Δgrad| across backends/lanes: ~1e-9


@pytest.fixture(scope="module")
def acm_han():
    _, data = build_problem("acm", scale=0.05, block=16, max_edges=20_000)
    params = init_han(jax.random.key(0), data, hidden=8, heads=2, att_dim=16)
    return data, params


def _loss_and_grad(data, params, fwd):
    def f(p):
        logp = jax.nn.log_softmax(fwd(p).astype(jnp.float32))
        return -jnp.take_along_axis(logp, data.labels[:, None], 1).mean()

    loss, grads = jax.jit(jax.value_and_grad(f))(params)
    return float(loss), grads


def _grad_maxdiff(a, b):
    return max(
        float(jnp.max(jnp.abs(x - y)))
        for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))
    )


def test_han_train_step_differential_backends(acm_han):
    """Jitted HAN loss+grad across NA backends: loss bit-identical, grads
    at f32 tolerance (MULTIGRAPH's custom-VJP recompute backward vs
    autodiff)."""
    data, params = acm_han
    backends = [
        NABackend.BLOCK,
        cpu_fallback(NABackend.MULTIGRAPH),  # compiled on TPU, interpret on CPU
        NABackend.MULTIGRAPH_INTERPRET,
    ]
    results = [
        _loss_and_grad(data, params, lambda p, b=b: han_forward(p, data, backend=b))
        for b in backends
    ]
    base_loss, base_grads = results[0]
    for loss, grads in results[1:]:
        assert loss == base_loss  # bitwise
        assert _grad_maxdiff(grads, base_grads) <= GRAD_ATOL


@pytest.mark.parametrize("lanes", [1, 2, 4])
def test_han_train_step_differential_lane_counts(acm_han, lanes):
    """Jitted HAN loss+grad through the lane-sharded kernel path under
    shard_map: loss bit-identical to the single-chip BLOCK path for every
    lane count, grads at f32 tolerance, and bit-deterministic on repeat
    (fixed topology)."""
    data, params = acm_han
    base_loss, base_grads = _loss_and_grad(
        data, params, lambda p: han_forward(p, data, backend=NABackend.BLOCK)
    )
    plan = build_multilane_plan(data.graphs, lanes)
    mesh = make_lane_mesh(lanes, 1)
    fwd = lambda p: han_forward_multilane(
        p, data, plan, mesh=mesh, backend="kernel_interpret"
    )
    loss, grads = _loss_and_grad(data, params, fwd)
    assert loss == base_loss  # bitwise, any lane count
    assert _grad_maxdiff(grads, base_grads) <= GRAD_ATOL
    loss2, grads2 = _loss_and_grad(data, params, fwd)
    assert loss2 == loss and _grad_maxdiff(grads2, grads) == 0.0  # deterministic


# --- property tests: plan builders + multigraph VJP on degenerate shapes ---


def _sg(name, src, dst, n):
    return SemanticGraph(
        name=name, src_type="v", dst_type="v",
        src_ids=np.asarray(src, np.int32), dst_ids=np.asarray(dst, np.int32),
        num_src=n, num_dst=n, path_types=("v", "v"),
    )


def _draw_batches(data_obj, *, with_degenerates: bool):
    block = data_obj.draw(st.sampled_from([4, 8]))
    n_blocks = data_obj.draw(st.integers(1, 3))
    n = block * n_blocks
    graphs = []
    if with_degenerates:
        graphs.append(_sg("empty", [], [], n))  # zero edges: all rows padded
        graphs.append(_sg("single", [n - 1], [0], n))
    n_rand = data_obj.draw(st.integers(1, 2))
    for gi in range(n_rand):
        n_edges = data_obj.draw(st.integers(0, 30))
        # unique (src, dst) pairs: block masks are boolean, duplicates
        # would break the edge-conservation invariant
        pairs = data_obj.draw(
            st.lists(st.integers(0, n * n - 1), min_size=n_edges, max_size=n_edges)
        )
        pairs = sorted(set(pairs))
        src = [p // n for p in pairs]
        dst = [p % n for p in pairs]
        graphs.append(_sg(f"rand{gi}", src, dst, n))
    return [batch_semantic_graph(s, block=block) for s in graphs], n


@settings(max_examples=10, deadline=None)
@given(st.data())
def test_plan_builders_fuzz_invariants(data_obj):
    """build_unit_tables / build_multilane_plan over random unit tables:
    every (graph, dst-row) is exactly one work unit, edges are conserved
    through the block masks, and lane loads account for every edge."""
    batches, n = _draw_batches(data_obj, with_degenerates=True)
    lanes = data_obj.draw(st.integers(1, 4))
    G = len(batches)
    n_rows = int(batches[0].col_index.shape[0])
    total_edges = sum(int(b.row_edge_counts().sum()) for b in batches)

    col, gid, drow, masks = build_unit_tables(batches)
    assert col.shape[0] == G * n_rows == gid.shape[0] == drow.shape[0]
    units = sorted(zip(np.asarray(gid).tolist(), np.asarray(drow).tolist()))
    assert units == [(g, r) for g in range(G) for r in range(n_rows)]
    assert int(np.asarray(masks).sum()) == total_edges

    plan = build_multilane_plan(batches, lanes)
    valid = np.asarray(plan.valid)
    assert int(valid.sum()) == G * n_rows
    plan_units = sorted(
        (int(g), int(r))
        for g, r, v in zip(
            np.asarray(plan.graph_id).ravel(),
            np.asarray(plan.dst_row).ravel(),
            valid.ravel(),
        )
        if v
    )
    assert plan_units == units  # disjoint + complete partition
    assert int(np.asarray(masks).sum()) == int(plan.lane_plan.lane_load.sum())


@settings(max_examples=6, deadline=None)
@given(st.data())
def test_multilane_vjp_fuzz_degenerate_shapes(data_obj):
    """Forward and VJP of the multigraph kernel over random plans with
    forced degenerate members (empty graph, single edge, all-padded rows):
    reference and kernel agree, degenerate rows are exact zeros (forward
    AND gradient), and nothing is NaN."""
    batches, n = _draw_batches(data_obj, with_degenerates=True)
    lanes = data_obj.draw(st.integers(1, 4))
    plan = build_multilane_plan(batches, lanes)
    G, H, Dh = len(batches), 2, 4
    n_pad = plan.n_dst_blocks * plan.block
    rng = np.random.default_rng(data_obj.draw(st.integers(0, 2**31)))
    hs = jnp.asarray(rng.standard_normal((n_pad, H, Dh)).astype(np.float32))
    ths = jnp.asarray(rng.standard_normal((G, n_pad, H)).astype(np.float32))
    thd = jnp.asarray(rng.standard_normal((G, n_pad, H)).astype(np.float32))

    outs, grads = {}, {}
    for be in ("reference", "kernel_interpret"):
        z = multilane_na(plan, ths, thd, hs, backend=be)
        assert np.isfinite(np.asarray(z)).all(), be
        assert np.all(np.asarray(z[0]) == 0.0), be  # empty graph: exact zeros
        outs[be] = np.asarray(z)
        g = jax.grad(
            lambda a, b, c: jnp.sum(multilane_na(plan, a, b, c, backend=be) ** 2),
            argnums=(0, 1, 2),
        )(ths, thd, hs)
        for leaf in g:
            assert np.isfinite(np.asarray(leaf)).all(), be
        assert np.all(np.asarray(g[0][0]) == 0.0), be  # d_theta_src of empty graph
        assert np.all(np.asarray(g[1][0]) == 0.0), be  # d_theta_dst of empty graph
        grads[be] = g
    np.testing.assert_allclose(outs["kernel_interpret"], outs["reference"], atol=1e-5)
    for a, b in zip(grads["kernel_interpret"], grads["reference"]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)
