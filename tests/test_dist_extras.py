"""dist internals beyond test_dist.py: context nesting, param_shardings
on a real model pytree, shard under a live mesh, lane-axis execution."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec

from repro.dist.sharding import (
    Rules,
    active_rules,
    lane_axes,
    make_rules,
    param_shardings,
    shard,
    use_rules,
)
from repro.launch.mesh import make_lane_mesh, make_mesh


def test_active_rules_nesting_and_restoration():
    assert active_rules() is None
    outer = make_rules(fsdp=True)
    inner = make_rules(parallelism="sp")
    with use_rules(outer):
        assert active_rules() is outer
        with use_rules(inner):
            assert active_rules() is inner
        assert active_rules() is outer  # innermost popped, outer restored
    assert active_rules() is None


def test_use_rules_restores_on_exception():
    r = make_rules()
    with pytest.raises(RuntimeError):
        with use_rules(r):
            raise RuntimeError("boom")
    assert active_rules() is None


def test_unknown_logical_axis_replicates():
    r = make_rules(fsdp=True)
    assert r.spec(("totally_new_axis", "heads")) == PartitionSpec(None, "model")
    assert r.mesh_axes("totally_new_axis") is None


def test_lanes_rules():
    r = make_rules(parallelism="lanes")
    assert r.spec(("act_lane", None, None)) == PartitionSpec("lane", None, None)
    assert r.spec((None, None, "act_feat")) == PartitionSpec(None, None, "model")
    # lane meshes have no `data` axis: nothing in the lanes table may
    # reference it, whatever the batch_shard/fsdp flags say
    assert r.spec(("act_batch", "embed")) == PartitionSpec(None, None)
    rfs = make_rules(parallelism="lanes", fsdp=True, batch_shard=True)
    assert rfs.spec(("act_batch", "embed")) == PartitionSpec(None, None)
    rmp = make_rules(parallelism="lanes", multi_pod=True)
    assert rmp.spec(("act_lane", None)) == PartitionSpec(("pod", "lane"), None)


def test_lane_axes_helper():
    """lane_axes derives the multilane shard axes from the rules — a
    hardcoded ("lane",) would drop the pod axis under multi_pod."""
    assert lane_axes(make_rules(parallelism="lanes")) == ("lane",)
    assert lane_axes(make_rules(parallelism="lanes", multi_pod=True)) == ("pod", "lane")
    with pytest.raises(AssertionError, match="lane axis"):
        lane_axes(make_rules())  # tp posture maps no lane dimension


def test_param_shardings_on_real_model_pytree():
    from repro.configs import smoke_config
    from repro.models.lm.api import build
    from repro.optim import AdamWConfig
    from repro.train.step import init_train_state, train_state_axes

    cfg = smoke_config("llama3.2-3b")
    api = build(cfg)
    rules = make_rules(fsdp=True)
    mesh = make_mesh((1, 1), ("data", "model"))
    opt = AdamWConfig()
    state_abs = jax.eval_shape(
        lambda k: init_train_state(api, k, opt), jax.random.key(0)
    )
    axes = train_state_axes(api, opt, state_abs.params)
    sh = param_shardings(mesh, rules, axes)
    # same tree structure as the abstract state (master slots are None
    # for fp32 params and stay None)
    assert jax.tree_util.tree_structure(sh) == jax.tree_util.tree_structure(
        jax.tree_util.tree_map(lambda x: 0, state_abs)
    )
    leaves = jax.tree_util.tree_leaves(sh)
    assert leaves and all(isinstance(s, NamedSharding) for s in leaves)
    # known leaves: the embedding is ("vocab", "embed") -> (model, data)
    assert sh.params["embed"].spec == PartitionSpec("model", "data")
    # scalar step counter is fully replicated
    assert sh.step.spec == PartitionSpec()
    # shardings are materialisable: device_put a real state through them
    state = init_train_state(api, jax.random.key(0), opt)
    state = jax.device_put(state, sh)
    assert state.params["embed"].sharding.spec == PartitionSpec("model", "data")


def test_shard_applies_under_mesh_and_rules():
    mesh = make_mesh((1, 1), ("data", "model"))
    rules = make_rules(fsdp=True)
    x = jnp.arange(16.0).reshape(4, 4)
    with mesh, use_rules(rules):
        y = shard(x, "act_batch", "act_mlp")
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        # inside jit the constraint must trace cleanly AND show up in the
        # lowered program (i.e. shard() is not silently a no-op here)
        lowered = jax.jit(lambda a: shard(a * 2, "act_batch", None)).lower(x)
        assert "sharding" in lowered.as_text()
        z = jax.jit(lambda a: shard(a * 2, "act_batch", None))(x)
    np.testing.assert_array_equal(np.asarray(z), np.asarray(x) * 2)
    # rules without a mesh: no-op, not an error
    with use_rules(rules):
        np.testing.assert_array_equal(np.asarray(shard(x, "act_batch", None)), np.asarray(x))


def test_hgnn_forward_under_rules_matches_plain():
    """The shard() hook points in models/hgnn must be numerically inert."""
    from repro.graphs import build_semantic_graphs, dataset_metapaths, synthetic_hetgraph
    from repro.models.hgnn import MODELS, prepare_data

    g = synthetic_hetgraph("imdb", scale=0.05, feat_scale=0.1)
    sgs = build_semantic_graphs(g, dataset_metapaths("imdb"))
    data = prepare_data(g, sgs, "movie", 3, block=16)
    model = MODELS["HAN"]
    params = model.init(jax.random.key(0), data)
    ref = model.forward(params, data)
    mesh = make_lane_mesh(1, 1)
    with mesh, use_rules(make_rules(parallelism="lanes")):
        out = model.forward(params, data)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6, atol=1e-6)


def test_make_lane_mesh_geometry():
    mesh = make_lane_mesh(1, 1)
    assert mesh.axis_names == ("lane", "model")
    assert dict(mesh.shape) == {"lane": 1, "model": 1}


def test_multilane_na_sharded_matches_vmap_path():
    from repro.core import batch_semantic_graph
    from repro.core.multilane import build_multilane_plan, multilane_na, multilane_na_sharded
    from repro.graphs import build_semantic_graphs, dataset_metapaths, synthetic_hetgraph

    g = synthetic_hetgraph("dblp", scale=0.05, feat_scale=0.1)
    sgs = build_semantic_graphs(g, dataset_metapaths("dblp"))
    batches = [batch_semantic_graph(s, block=16) for s in sgs]
    plan = build_multilane_plan(batches, 4)
    rng = np.random.default_rng(0)
    G, ns = len(batches), batches[0].num_src
    ns_pad = ((ns + 15) // 16) * 16
    ths = jnp.asarray(rng.standard_normal((G, ns_pad, 2)).astype(np.float32))
    thd = jnp.asarray(rng.standard_normal((G, batches[0].num_dst_pad, 2)).astype(np.float32))
    hs = jnp.asarray(rng.standard_normal((ns_pad, 2, 4)).astype(np.float32))
    ref = multilane_na(plan, ths, thd, hs)
    mesh = make_lane_mesh(1, 1)
    out = multilane_na_sharded(plan, ths, thd, hs, mesh=mesh)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6, atol=1e-6)
    # jit-through: the shard_map executor must be traceable with the plan
    # as a pytree argument (regression for the MultiLanePlan aux contract)
    out2 = jax.jit(lambda p: multilane_na_sharded(p, ths, thd, hs, mesh=mesh))(plan)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(ref), rtol=1e-6, atol=1e-6)
    # fused-kernel backend through shard_map (one Pallas launch per shard)
    out3 = multilane_na_sharded(plan, ths, thd, hs, mesh=mesh, backend="kernel_interpret")
    np.testing.assert_allclose(np.asarray(out3), np.asarray(ref), rtol=1e-5, atol=1e-5)
