"""End-to-end behaviour tests for the paper's system: the full HiHGNN
pipeline (SGB -> similarity schedule -> lane balance -> fused execution ->
training) on synthetic Table-5 datasets."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    NABackend,
    batch_semantic_graph,
    count_reuse,
    fp_buffer_traffic,
    similarity_schedule,
)
from repro.core.multilane import build_multilane_plan
from repro.graphs import (
    build_semantic_graphs,
    dataset_metapaths,
    dataset_target,
    synthetic_hetgraph,
    synthetic_labels,
)
from repro.models.hgnn import MODELS, cross_entropy, prepare_data


def test_full_hihgnn_pipeline_dblp():
    """SGB → similarity-aware order → workload-aware lanes → fused HAN
    training: every paper component in one flow."""
    g = synthetic_hetgraph("dblp", scale=0.2, feat_scale=0.08, seed=0)
    target, ncls = dataset_target("dblp")
    labels = synthetic_labels(g, "dblp")

    # 1. SGB (host preprocessing, as in the paper)
    sgs = build_semantic_graphs(g, dataset_metapaths("dblp"), max_edges=50000)
    assert all(s.num_edges > 0 for s in sgs)

    # 2. similarity-aware execution scheduling
    order, w = similarity_schedule(sgs, g.vertex_counts)
    assert sorted(order) == list(range(len(sgs)))

    # 3. workload-aware lane balance over block rows
    batches = [batch_semantic_graph(s, block=16) for s in sgs]
    plan = build_multilane_plan(batches, 4)
    assert plan.lane_plan.imbalance() <= build_multilane_plan(
        batches, 4, balanced=False
    ).lane_plan.imbalance()

    # 4. fused execution + training (Adam; connected vertices must be fit —
    # isolated ones carry an irreducible class-prior loss at small scale)
    from repro.optim import AdamWConfig, apply_updates, init_opt_state

    data = prepare_data(g, [sgs[i] for i in order], target, ncls, labels, block=16)
    model = MODELS["HAN"]
    params = model.init(jax.random.key(0), data)
    opt = AdamWConfig(lr=5e-3, weight_decay=0.0)
    ostate = init_opt_state(params, opt)

    @jax.jit
    def step(p, s):
        loss, grads = jax.value_and_grad(
            lambda p_: cross_entropy(model.forward(p_, data), data.labels)
        )(p)
        p, s, _ = apply_updates(p, grads, s, opt, jnp.asarray(5e-3))
        return p, s, loss

    losses = []
    for _ in range(80):
        params, ostate, loss = step(params, ostate)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.85


def test_rab_dedup_saves_work_at_scale():
    """At full Table-5 scale the RAB-style dedup must save the bulk of
    projections and coefficient computations (paper §4.3.1)."""
    g = synthetic_hetgraph("acm", scale=1.0, feat_scale=0.02, seed=0)
    sgs = build_semantic_graphs(g, dataset_metapaths("acm"), max_edges=500_000)
    c = count_reuse(sgs, g.vertex_counts)
    assert c.fp_saved > 0.4       # projections reused across semantic graphs
    assert c.theta_saved > 0.5    # coefficients reused across edges


def test_similarity_order_maximizes_fp_reuse():
    """Fig. 15 mechanism: with FP-Buf smaller than the total projected
    footprint, the Hamilton-path order reuses >= random orders on average."""
    g = synthetic_hetgraph("acm", scale=0.3, feat_scale=0.1, seed=1)
    # widen the metapath set (the paper sweeps 4/8/12 semantic graphs)
    mps = [
        ("paper", "author", "paper"),
        ("paper", "subject", "paper"),
        ("paper", "term", "paper"),
        ("author", "paper", "author"),
        ("author", "paper", "subject", "paper", "author"),
        ("subject", "paper", "subject"),
        ("term", "paper", "term"),
        ("paper", "paper", "author", "paper"),
    ]
    sgs = build_semantic_graphs(g, mps, max_edges=30000)
    order, _ = similarity_schedule(sgs, g.vertex_counts)
    bpv = {t: g.feature_dim(t) * 4 for t in g.vertex_counts}
    buf = sum(g.vertex_counts[t] * bpv[t] for t in g.vertex_counts) // 4
    reuse_sim = fp_buffer_traffic(
        order, sgs, g.vertex_counts, bytes_per_vertex=bpv, fpbuf_bytes=buf
    ).reuse_fraction
    rng = np.random.default_rng(0)
    rand = [
        fp_buffer_traffic(
            list(rng.permutation(len(sgs))), sgs, g.vertex_counts,
            bytes_per_vertex=bpv, fpbuf_bytes=buf,
        ).reuse_fraction
        for _ in range(20)
    ]
    assert reuse_sim >= np.mean(rand) - 1e-9
