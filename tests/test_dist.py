"""Sharding rules, hlostats parser, serving engine."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec

from repro.dist.sharding import Rules, make_rules, shard, use_rules
from repro.launch.hlostats import analyze


def test_rules_spec_mapping():
    r = make_rules(multi_pod=True, fsdp=True)
    assert r.spec(("act_batch", None, "act_vocab")) == PartitionSpec(("pod", "data"), None, "model")
    assert r.spec(("embed", "heads")) == PartitionSpec(("pod", "data"), "model")
    r1 = make_rules(multi_pod=False, fsdp=True)
    assert r1.spec(("embed", "heads")) == PartitionSpec("data", "model")
    # duplicate mesh axis within one spec is dropped (axis used once)
    assert r.spec(("heads", "mlp")) == PartitionSpec("model", None)
    r2 = make_rules(batch_shard=False)
    assert r2.spec(("act_batch", None)) == PartitionSpec(None, None)


def test_shard_is_noop_without_rules():
    x = jnp.ones((4, 4))
    y = shard(x, "act_batch", None)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_hlostats_loop_correction_synthetic():
    hlo = """
HloModule test

%cond (p: (s32[], f32[128,128])) -> pred[] {
  %p = (s32[], f32[128,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body (p: (s32[], f32[128,128])) -> (s32[], f32[128,128]) {
  %p = (s32[], f32[128,128]) parameter(0)
  %x = f32[128,128] get-tuple-element(%p), index=1
  %ar = f32[128,128]{1,0} all-reduce(%x), replica_groups={}
  %d = f32[128,128]{1,0} dot(%ar, %ar), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %i = s32[] get-tuple-element(%p), index=0
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[128,128]) tuple(%ni, %d)
}

ENTRY %main (a: f32[128,128]) -> f32[128,128] {
  %a = f32[128,128] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[128,128]) tuple(%zero, %a)
  %w = (s32[], f32[128,128]) while(%init), condition=%cond, body=%body
  %ag = f32[256,128]{1,0} all-gather(%a), dimensions={0}
  ROOT %out = f32[128,128] get-tuple-element(%w), index=1
}
"""
    st = analyze(hlo)
    assert st.while_trips == [12]
    # all-reduce inside the loop: 128*128*4 bytes * 12
    assert abs(st.collective_bytes["all-reduce"] - 128 * 128 * 4 * 12) < 1
    # all-gather outside: counted once with its (result) size
    assert abs(st.collective_bytes["all-gather"] - 256 * 128 * 4) < 1
    # dot: 2*128^3 flops * 12
    assert abs(st.dot_flops - 2 * 128**3 * 12) < 1


def test_greedy_generate_runs():
    from repro.configs import smoke_config
    from repro.models.lm.api import build
    from repro.serve.engine import greedy_generate

    cfg = smoke_config("llama3.2-3b")
    api = build(cfg)
    params = api.init(jax.random.key(0))
    out = greedy_generate(api, params, jnp.array([[1, 2, 3]], jnp.int32), steps=4, cache_len=12)
    assert out.shape == (1, 4)
    assert (np.asarray(out) >= 0).all() and (np.asarray(out) < cfg.vocab_size).all()
