"""Continuous batching, gradient compression, M-RoPE/qk-norm properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.models.lm.api import build


def test_continuous_batcher_matches_sequential():
    """Requests decoded through the continuous batcher must produce the
    same greedy tokens as one-at-a-time generation."""
    from repro.serve.batcher import ContinuousBatcher, Request
    from repro.serve.engine import greedy_generate

    cfg = smoke_config("llama3.2-3b")
    api = build(cfg)
    params = api.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 5).tolist() for _ in range(5)]

    # sequential reference
    refs = []
    for p in prompts:
        out = greedy_generate(
            api, params, jnp.asarray([p], jnp.int32), steps=4, cache_len=32
        )
        refs.append(np.asarray(out)[0].tolist())

    # continuous batcher: 3 slots for 5 requests -> at least one slot reuse
    cb = ContinuousBatcher(api, num_slots=3, cache_len=32, params=params)
    for i, p in enumerate(prompts):
        cb.submit(Request(rid=i, prompt=p, max_new=4))
    finished = cb.run()
    assert len(finished) == 5
    got = {r.rid: r.out for r in finished}
    for i, ref in enumerate(refs):
        assert got[i] == ref, (i, got[i], ref)


def test_continuous_batcher_midstream_admission_tight_cache():
    """Per-slot cache positions: requests admitted mid-stream must decode
    correctly even when the TOTAL number of engine steps far exceeds
    ``cache_len``.  (The earlier shared-global-counter design clamped the
    position at ``cache_len`` — later waves then overwrote one ring slot
    and diverged from sequential decoding.)"""
    from repro.serve.batcher import ContinuousBatcher, Request
    from repro.serve.engine import greedy_generate

    cfg = smoke_config("llama3.2-3b")
    api = build(cfg)
    params = api.init(jax.random.key(1))
    rng = np.random.default_rng(1)
    # varied prompt/max_new so slots free at different times (staggered
    # waves); each request fits cache_len=16 but the run takes ~30 steps
    jobs = [(rng.integers(0, cfg.vocab_size, 4 + i % 4).tolist(), 3 + i % 3)
            for i in range(6)]

    refs = []
    for p, n in jobs:
        out = greedy_generate(
            api, params, jnp.asarray([p], jnp.int32), steps=n, cache_len=16
        )
        refs.append(np.asarray(out)[0, :n].tolist())

    cb = ContinuousBatcher(api, num_slots=2, cache_len=16, params=params)
    for i, (p, n) in enumerate(jobs):
        cb.submit(Request(rid=i, prompt=p, max_new=n))
    finished = cb.run()
    assert len(finished) == 6
    total_steps = sum(len(p) + n for p, n in jobs) // 2  # ~2 slots busy
    assert total_steps > 16  # the regime the shared counter could not serve
    got = {r.rid: r.out for r in finished}
    for i, ref in enumerate(refs):
        assert got[i] == ref, (i, got[i], ref)


def test_gradient_compression_close_to_fp32():
    from repro.data import SyntheticLMData
    from repro.optim import AdamWConfig
    from repro.train import make_train_step
    from repro.train.step import init_train_state

    cfg = smoke_config("llama3.2-3b")
    api = build(cfg)
    opt = AdamWConfig(lr=1e-2, weight_decay=0.0)
    sched = lambda s: jnp.asarray(1e-2)
    data = SyntheticLMData(vocab_size=cfg.vocab_size, seq_len=16, global_batch=8, seed=3)
    batch = data.next()
    s32 = init_train_state(api, jax.random.key(0), opt)
    sbf = init_train_state(api, jax.random.key(0), opt)
    step32 = jax.jit(make_train_step(api, opt, microbatches=2, lr_schedule=sched))
    stepbf = jax.jit(make_train_step(api, opt, microbatches=2, lr_schedule=sched, grad_dtype="bfloat16"))
    a, ma = step32(s32, batch)
    b, mb = stepbf(sbf, batch)
    # bf16 wire-compressed gradients stay close to fp32 gradients
    np.testing.assert_allclose(float(ma["loss"]), float(mb["loss"]), rtol=1e-5)
    gn32, gnbf = float(ma["grad_norm"]), float(mb["grad_norm"])
    assert abs(gn32 - gnbf) / gn32 < 0.05, (gn32, gnbf)
    # post-update params stay close (bf16 mantissa ≈ 8 bits -> ~0.4% grads;
    # one optimizer step amplifies via rsqrt(v), so tolerate lr-scale drift)
    for x, y in zip(jax.tree_util.tree_leaves(a.params), jax.tree_util.tree_leaves(b.params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=0.5, atol=2e-2)


def test_mrope_reduces_to_rope_for_text():
    """Qwen2-VL M-RoPE with t==h==w positions must equal plain RoPE."""
    from repro.models.lm.layers import mrope_angles, rope_angles

    pos = jnp.arange(32, dtype=jnp.int32)[None, :]  # [1, S]
    plain = rope_angles(pos, 128, 1e6)
    m = mrope_angles(
        jnp.broadcast_to(pos[..., None], (1, 32, 3)), 128, 1e6, (16, 24, 24)
    )
    np.testing.assert_allclose(np.asarray(plain), np.asarray(m), rtol=1e-6)


def test_qk_norm_normalizes_per_head():
    from repro.models.lm.layers import rms_norm

    x = jax.random.normal(jax.random.key(0), (2, 4, 3, 16)) * 5.0
    y = rms_norm(x, jnp.ones((16,)))
    rms = np.sqrt(np.mean(np.asarray(y) ** 2, axis=-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)
