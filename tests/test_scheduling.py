"""Scheduling properties: Hamilton path optimality, workload balance."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.scheduling import (
    brute_force_hamilton_path,
    lane_assignment,
    naive_lane_assignment,
    shortest_hamilton_path,
    similarity_matrix,
)
from repro.graphs import build_semantic_graphs, dataset_metapaths, synthetic_hetgraph


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 6), st.integers(0, 10_000))
def test_held_karp_equals_brute_force(n, seed):
    rng = np.random.default_rng(seed)
    w = rng.random((n, n))
    w = (w + w.T) / 2
    np.fill_diagonal(w, 0)
    order_hk, cost_hk = shortest_hamilton_path(w)
    _, cost_bf = brute_force_hamilton_path(w)
    assert sorted(order_hk) == list(range(n))  # visits every vertex once
    assert abs(cost_hk - cost_bf) < 1e-9
    # reported cost is consistent with the path itself
    path_cost = sum(w[order_hk[i], order_hk[i + 1]] for i in range(n - 1))
    assert abs(path_cost - cost_hk) < 1e-9


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_lane_assignment_balances(data):
    n_graphs = data.draw(st.integers(1, 5))
    num_lanes = data.draw(st.integers(1, 8))
    rng = np.random.default_rng(data.draw(st.integers(0, 999)))
    row_costs = [
        rng.integers(0, 100, size=rng.integers(1, 20)).astype(float)
        for _ in range(n_graphs)
    ]
    plan = lane_assignment(row_costs, num_lanes)
    naive = naive_lane_assignment(row_costs, num_lanes)
    # every unit assigned to exactly one lane
    assert (plan.unit_lane >= 0).all() and (plan.unit_lane < num_lanes).all()
    assert plan.unit_cost.sum() == naive.unit_cost.sum()
    # balanced assignment never worse than naive (max lane load)
    assert plan.lane_load.max() <= naive.lane_load.max() + 1e-9
    # no lane exceeds threshold by more than the largest single unit
    total = plan.unit_cost.sum()
    thresh = np.ceil(total / num_lanes)
    biggest = plan.unit_cost.max() if plan.unit_cost.size else 0
    assert plan.lane_load.max() <= thresh + biggest + 1e-9


def test_similarity_matrix_paper_formula():
    g = synthetic_hetgraph("acm", scale=0.05, feat_scale=0.1)
    sgs = build_semantic_graphs(g, dataset_metapaths("acm"), max_edges=2000)
    w = similarity_matrix(sgs, g.vertex_counts)
    assert w.shape == (4, 4)
    assert np.allclose(w, w.T) and np.allclose(np.diag(w), 0)
    assert (w >= 0).all() and (w <= 1).all()
    # PAP vs PPAP share {paper, author}; PAP vs PSP share only {paper}
    i_pap = [s.name for s in sgs].index("PAP")
    i_ppap = [s.name for s in sgs].index("PPAP")
    i_psp = [s.name for s in sgs].index("PSP")
    assert w[i_pap, i_ppap] < w[i_pap, i_psp]  # more shared types => lower weight
