"""Per-assigned-architecture smoke tests: reduced config, one forward +
one train step on CPU, asserting output shapes and finiteness — exactly
what the brief requires for deliverable (f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, cell_supported, get_config, grid, smoke_config
from repro.models.lm.api import build
from repro.models.lm.transformer import vocab_padded
from repro.optim import AdamWConfig
from repro.train import make_train_step
from repro.train.step import init_train_state


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = smoke_config(arch)
    api = build(cfg)
    opt = AdamWConfig(lr=1e-2, weight_decay=0.0)
    state = init_train_state(api, jax.random.key(0), opt)
    B, S = 2, 16
    batch = {"tokens": jax.random.randint(jax.random.key(1), (B, S + 1), 0, cfg.vocab_size)}
    if cfg.frontend == "audio":
        batch["frames"] = jnp.zeros((B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    if cfg.frontend == "vision":
        batch["visual_embeds"] = jnp.zeros((B, 4, cfg.d_model), jnp.float32)
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None, :, None], (B, S, 3)
        )
    step = jax.jit(make_train_step(api, opt, lr_schedule=lambda s: jnp.asarray(1e-2)))
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state2.step) == 1
    # params actually changed
    before = jax.tree_util.tree_leaves(state.params)[0]
    after = jax.tree_util.tree_leaves(state2.params)[0]
    assert not np.allclose(np.asarray(before), np.asarray(after))


@pytest.mark.parametrize("arch", ["qwen2-7b", "recurrentgemma-9b", "mamba2-2.7b", "whisper-large-v3"])
def test_smoke_decode_step(arch):
    from repro.serve.engine import init_serve_state, make_serve_step, make_prefill

    cfg = smoke_config(arch)
    api = build(cfg)
    params = api.init(jax.random.key(0))
    B, S = 2, 8
    state = init_serve_state(api, B, 16, dtype=jnp.float32)
    prefill = make_prefill(api)
    kw = {}
    if cfg.is_encoder_decoder:
        kw["frames"] = jnp.zeros((B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    logits, state = prefill(params, state, toks, **kw)
    assert logits.shape == (B, vocab_padded(cfg))
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    serve = make_serve_step(api)
    logits2, state = serve(params, state, toks[:, :1])
    assert int(state.cache_pos) == S + 1
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


def test_grid_cells_and_skips():
    cells = grid()
    assert len(cells) == 40
    skipped = [(a, s) for a, s, ok, _ in cells if not ok]
    # exactly the 8 pure-full-attention archs skip long_500k
    assert len(skipped) == 8
    assert all(s == "long_500k" for _, s in skipped)
    assert ("mamba2-2.7b", "long_500k") not in skipped
    assert ("recurrentgemma-9b", "long_500k") not in skipped


def test_published_param_counts():
    """Configs must land near their published sizes (±15%)."""
    expected = {
        "qwen2-vl-7b": 8.3e9,  # qwen2-vl reports 8.3B incl. vision tower; backbone ~7.6
        "llama3.2-3b": 3.2e9,
        "qwen2-7b": 7.6e9,
        "qwen3-8b": 8.2e9,
        "minitron-4b": 4.2e9,
        "mamba2-2.7b": 2.7e9,
        "whisper-large-v3": 1.5e9,
        "recurrentgemma-9b": 9.8e9,
        "dbrx-132b": 132e9,
        "grok-1-314b": 314e9,
    }
    for arch, want in expected.items():
        got = get_config(arch).param_count()
        assert abs(got - want) / want < 0.15, (arch, got, want)
