"""HGNN model semantics: all four Table-2 models, backend equivalence,
staged-vs-fused equivalence, and end-to-end training on synthetic ACM."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import NABackend
from repro.graphs import (
    build_semantic_graphs,
    dataset_metapaths,
    dataset_target,
    relation_semantic_graphs,
    synthetic_hetgraph,
    synthetic_labels,
)
from repro.models.hgnn import MODELS, cross_entropy, prepare_data
from repro.models.hgnn.han import han_forward_staged


@pytest.fixture(scope="module")
def acm():
    g = synthetic_hetgraph("acm", scale=0.12, feat_scale=0.1, seed=0)
    target, ncls = dataset_target("acm")
    labels = synthetic_labels(g, "acm")
    mp = build_semantic_graphs(g, dataset_metapaths("acm"), max_edges=20000)
    rel = relation_semantic_graphs(g)
    return g, target, ncls, labels, mp, rel


@pytest.mark.parametrize("name", ["HAN", "R-GCN", "R-GAT", "S-HGN"])
def test_model_forward_shapes_finite(acm, name):
    g, target, ncls, labels, mp, rel = acm
    data = prepare_data(g, mp if name == "HAN" else rel, target, ncls, labels, block=16)
    model = MODELS[name]
    params = model.init(jax.random.key(0), data)
    logits = model.forward(params, data, backend=NABackend.SEGMENT)
    assert logits.shape == (g.num_vertices(target), ncls)
    assert np.isfinite(np.asarray(logits)).all()


def test_han_backends_and_staged_agree(acm):
    g, target, ncls, labels, mp, _ = acm
    data = prepare_data(g, mp, target, ncls, labels, block=16)
    model = MODELS["HAN"]
    params = model.init(jax.random.key(1), data)
    l_seg = model.forward(params, data, backend=NABackend.SEGMENT)
    l_blk = model.forward(params, data, backend=NABackend.BLOCK)
    l_staged = han_forward_staged(params, data)
    np.testing.assert_allclose(np.asarray(l_seg), np.asarray(l_blk), rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(l_seg), np.asarray(l_staged), rtol=5e-4, atol=5e-4)


def test_han_kernel_backend_matches(acm):
    """The Pallas kernel (interpret mode) is a drop-in NA backend."""
    g, target, ncls, labels, mp, _ = acm
    data = prepare_data(g, mp[:1], target, ncls, labels, block=16)
    model = MODELS["HAN"]
    params = model.init(jax.random.key(2), data)
    l_seg = model.forward(params, data, backend=NABackend.SEGMENT)
    l_ker = model.forward(params, data, backend=NABackend.KERNEL_INTERPRET)
    np.testing.assert_allclose(np.asarray(l_seg), np.asarray(l_ker), rtol=5e-4, atol=5e-4)


def test_han_multigraph_backend_matches_and_trains(acm):
    """The consolidated path (ONE fused multigraph launch for all
    relations, fwd + custom-VJP bwd) is a drop-in HAN backend."""
    g, target, ncls, labels, mp, _ = acm
    data = prepare_data(g, mp, target, ncls, labels, block=16)
    model = MODELS["HAN"]
    params = model.init(jax.random.key(2), data)
    l_blk = model.forward(params, data, backend=NABackend.BLOCK)
    l_mg = model.forward(params, data, backend=NABackend.MULTIGRAPH_INTERPRET)
    np.testing.assert_allclose(np.asarray(l_mg), np.asarray(l_blk), rtol=5e-5, atol=5e-5)

    # gradients flow through the fused backward kernel and agree with
    # autodiff of the BLOCK oracle
    def loss(p, be):
        logits = model.forward(p, data, backend=be)
        return cross_entropy(logits, data.labels)

    g_mg = jax.grad(loss)(params, NABackend.MULTIGRAPH_INTERPRET)
    g_blk = jax.grad(loss)(params, NABackend.BLOCK)
    for k in g_blk:
        np.testing.assert_allclose(
            np.asarray(g_mg[k]), np.asarray(g_blk[k]), rtol=1e-3, atol=1e-5
        )


def test_shgn_edge_bias_matters(acm):
    """S-HGN's relation embedding term must influence the output."""
    g, target, ncls, labels, _, rel = acm
    data = prepare_data(g, rel, target, ncls, labels, block=16)
    model = MODELS["S-HGN"]
    params = model.init(jax.random.key(3), data)
    base = model.forward(params, data)
    bumped = jax.tree_util.tree_map(lambda x: x, params)
    bumped["layers"][0]["r_emb"] = params["layers"][0]["r_emb"] + 3.0
    assert not np.allclose(np.asarray(base), np.asarray(model.forward(bumped, data)))


def test_han_trains_on_synthetic_acm(acm):
    from repro.optim import AdamWConfig, apply_updates, init_opt_state
    import jax.numpy as jnp

    g, target, ncls, labels, mp, _ = acm
    data = prepare_data(g, mp, target, ncls, labels, block=16)
    model = MODELS["HAN"]
    params = model.init(jax.random.key(4), data)
    opt = AdamWConfig(lr=5e-3, weight_decay=0.0)
    ostate = init_opt_state(params, opt)

    @jax.jit
    def step(p, s):
        def loss_fn(p):
            logits = model.forward(p, data, backend=NABackend.SEGMENT)
            return cross_entropy(logits, data.labels)

        loss, grads = jax.value_and_grad(loss_fn)(p)
        p, s, _ = apply_updates(p, grads, s, opt, jnp.asarray(5e-3))
        return p, s, loss

    losses = []
    for _ in range(120):
        params, ostate, loss = step(params, ostate)
        losses.append(float(loss))
    # isolated vertices carry an irreducible class-prior loss; connected
    # vertices must be fit (loss well below ln(3)=1.1)
    assert losses[-1] < losses[0] * 0.8, losses[::16]
