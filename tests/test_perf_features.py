"""Features added during §Perf hillclimbing: serve2d rules, factored
optimizer, multilane-plan jit-ability, remat policies."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec

from repro.configs import smoke_config
from repro.data import SyntheticLMData
from repro.dist.sharding import make_rules
from repro.models.lm.api import build
from repro.optim import AdamWConfig, apply_updates, init_opt_state, opt_state_axes
from repro.train import make_train_step
from repro.train.step import init_train_state, train_state_axes


def test_serve2d_rules():
    r = make_rules(parallelism="serve2d", fsdp=True)
    # weights stay resident (embed over data, mlp over model)
    assert r.spec(("embed", "mlp")) == PartitionSpec("data", "model")
    # batch does NOT shard over data; activations' d-dim does
    assert r.spec(("act_batch", None, "act_embed")) == PartitionSpec(None, None, "data")
    assert r.spec(("act_batch", None, "act_mlp")) == PartitionSpec(None, None, "model")


def test_sp_rules():
    r = make_rules(parallelism="sp", fsdp=True)
    assert r.spec(("heads",)) == PartitionSpec(None)      # weights model-replicated
    assert r.spec(("act_batch", "act_seq", None)) == PartitionSpec("data", "model", None)


def test_factored_optimizer_state_is_small():
    cfg = smoke_config("grok-1-314b")
    api = build(cfg)
    params = api.init(jax.random.key(0))
    dense = init_opt_state(params, AdamWConfig())
    fact = init_opt_state(params, AdamWConfig(factored=True, master_fp32=False))
    nbytes = lambda t: sum(
        x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(t)
        if hasattr(x, "size")
    )
    # factored state must be a small fraction of dense Adam state
    assert nbytes(fact) < 0.15 * nbytes(dense)
    # axes tree matches state structure (required for dry-run shardings)
    axes = opt_state_axes(api.axes(), AdamWConfig(factored=True, master_fp32=False), params)
    jax.tree_util.tree_structure(axes)  # no mismatch raises


def test_factored_optimizer_descends():
    cfg = smoke_config("grok-1-314b")
    api = build(cfg)
    opt = AdamWConfig(lr=1e-2, weight_decay=0.0, factored=True, master_fp32=False)
    state = init_train_state(api, jax.random.key(0), opt)
    step = jax.jit(make_train_step(api, opt, lr_schedule=lambda s: jnp.asarray(1e-2)))
    data = SyntheticLMData(vocab_size=cfg.vocab_size, seq_len=16, global_batch=8, seed=1)
    losses = []
    for _ in range(40):
        state, m = step(state, data.next())
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses[::8]


def test_multilane_plan_jits_cleanly():
    """Regression: MultiLanePlan used to carry numpy arrays in pytree aux
    (unhashable) — jit of a plan-taking function crashed."""
    from repro.core import batch_semantic_graph
    from repro.core.multilane import build_multilane_plan, multilane_na
    from repro.graphs import build_semantic_graphs, dataset_metapaths, synthetic_hetgraph

    g = synthetic_hetgraph("dblp", scale=0.05, feat_scale=0.1)
    sgs = build_semantic_graphs(g, dataset_metapaths("dblp"))
    batches = [batch_semantic_graph(s, block=16) for s in sgs]
    plan = build_multilane_plan(batches, 2)
    rng = np.random.default_rng(0)
    G, ns = len(batches), batches[0].num_src
    ns_pad = ((ns + 15) // 16) * 16
    ths = jnp.asarray(rng.standard_normal((G, ns_pad, 2)).astype(np.float32))
    thd = jnp.asarray(rng.standard_normal((G, batches[0].num_dst_pad, 2)).astype(np.float32))
    hs = jnp.asarray(rng.standard_normal((ns_pad, 2, 4)).astype(np.float32))
    fn = jax.jit(lambda p: multilane_na(p, ths, thd, hs))
    out1 = fn(plan)
    out2 = fn(plan)  # second call exercises the jit cache-key path
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


@pytest.mark.parametrize("remat", ["none", "full", "dots"])
def test_remat_policies_agree(remat):
    """All remat policies must compute identical losses (HC1 iter 2)."""
    import dataclasses
    cfg = dataclasses.replace(smoke_config("qwen2-7b"), remat=remat)
    api = build(cfg)
    params = api.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
    logits, _ = api.forward(params, toks)
    base_cfg = dataclasses.replace(cfg, remat="none")
    base_api = build(base_cfg)
    ref, _ = base_api.forward(params, toks)
    np.testing.assert_allclose(
        np.asarray(logits, np.float32), np.asarray(ref, np.float32), rtol=1e-5, atol=1e-5
    )
