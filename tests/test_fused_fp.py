"""FP+NA stage-fusion megakernel (DESIGN.md §10): kernel vs reference,
VJP gradcheck, multigraph equivalence, multilane + sharded backends,
HAN end-to-end, and the serving engine's cache-aware dispatch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import NABackend, batch_semantic_graph
from repro.core.fusion import FusedFPInputs, build_unit_tables, neighbor_aggregate_multi
from repro.core.multilane import build_multilane_plan, multilane_na, multilane_na_sharded
from repro.graphs import (
    build_semantic_graphs,
    dataset_metapaths,
    dataset_target,
    synthetic_hetgraph,
    synthetic_labels,
)
from repro.kernels import fused_fp_na_reference, seg_gat_agg_fused_fp
from repro.models.hgnn import MODELS, cross_entropy, prepare_data

B, H, DH = 8, 2, 4


def _rand_tables(rng, *, units=6, width=3, nblk=5, graphs=3, tables=2, din=12):
    """Random flat unit tables + fused-FP operands (multi weight table)."""
    col = rng.integers(-1, nblk, (units, width)).astype(np.int32)
    col[:, 0] = np.maximum(col[:, 0], 0)  # at least one live block per unit
    gid = rng.integers(0, graphs, (units,)).astype(np.int32)
    row = rng.integers(0, nblk, (units,)).astype(np.int32)
    wsel = rng.integers(0, tables, (graphs,)).astype(np.int32)
    masks = rng.random((units, width, B, B)) < 0.6
    masks[:, 0, 0, 0] = True  # no fully-dead dst rows in live blocks
    n = nblk * B
    x = rng.standard_normal((n, din)).astype(np.float32)
    w = (rng.standard_normal((tables, din, H * DH)) / np.sqrt(din)).astype(np.float32)
    b = rng.standard_normal((tables, H * DH)).astype(np.float32) * 0.1
    a_s = rng.standard_normal((graphs, H, DH)).astype(np.float32)
    a_d = rng.standard_normal((graphs, H, DH)).astype(np.float32)
    bias = rng.standard_normal((graphs, H)).astype(np.float32) * 0.3
    return tuple(map(jnp.asarray, (col, gid, row, wsel, masks, x, w, b, a_s, a_d, bias)))


def test_fused_fp_forward_matches_reference_multi_table():
    args = _rand_tables(np.random.default_rng(0))
    out = seg_gat_agg_fused_fp(*args, interpret=True)
    ref = fused_fp_na_reference(*args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-5, atol=3e-6)


def test_fused_fp_matches_multigraph_on_materialized_h():
    """Fused FP+NA == project-then-multigraph-NA (the tentpole identity).

    Tolerance is pinned loose-ish (rtol 1e-4): the kernel reassociates the
    projection matmul per tile, so it is NOT bit-identical to a single
    HBM-materialized x@W."""
    from repro.kernels import seg_gat_agg_multigraph

    col, gid, row, wsel, masks, x, w, b, a_s, a_d, bias = _rand_tables(
        np.random.default_rng(1), tables=1)
    out = seg_gat_agg_fused_fp(
        col, gid, row, wsel, masks, x, w, b, a_s, a_d, bias, interpret=True)
    h = (x @ w[0] + b[0]).reshape(x.shape[0], H, DH)
    th_s = jnp.einsum("nhd,ghd->gnh", h, a_s)
    th_d = jnp.einsum("nhd,ghd->gnh", h, a_d)
    mg = seg_gat_agg_multigraph(
        col, gid, row, masks, th_s, th_d, h, bias, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(mg), rtol=1e-4, atol=1e-6)


def test_fused_fp_vjp_matches_reference_autodiff():
    args = _rand_tables(np.random.default_rng(2))
    fixed, diff = args[:5], args[5:]

    def loss_k(x, w, b, a_s, a_d, bias):
        return jnp.sin(seg_gat_agg_fused_fp(
            *fixed, x, w, b, a_s, a_d, bias, interpret=True)).sum()

    def loss_r(x, w, b, a_s, a_d, bias):
        return jnp.sin(fused_fp_na_reference(*fixed, x, w, b, a_s, a_d, bias)).sum()

    gk = jax.grad(loss_k, argnums=tuple(range(6)))(*diff)
    gr = jax.grad(loss_r, argnums=tuple(range(6)))(*diff)
    for name, a, e in zip(("x", "w", "b", "a_src", "a_dst", "bias"), gk, gr):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(e), rtol=1e-4, atol=1e-5, err_msg=name)


def test_fused_fp_dead_unit_is_zero_with_zero_grads():
    col, gid, row, wsel, masks, x, w, b, a_s, a_d, bias = _rand_tables(
        np.random.default_rng(3), units=4)
    col = col.at[2].set(-1)  # unit 2: every source block dead

    def f(x_):
        return seg_gat_agg_fused_fp(
            col, gid, row, wsel, masks, x_, w, b, a_s, a_d, bias, interpret=True)

    out = f(x)
    assert np.all(np.asarray(out[2 * B:3 * B]) == 0.0)
    g_x = jax.grad(lambda x_: f(x_)[2 * B:3 * B].sum())(x)
    np.testing.assert_allclose(np.asarray(g_x), 0.0, atol=1e-7)


@pytest.fixture(scope="module")
def acm():
    g = synthetic_hetgraph("acm", scale=0.12, feat_scale=0.1, seed=0)
    target, ncls = dataset_target("acm")
    labels = synthetic_labels(g, "acm")
    mp = build_semantic_graphs(g, dataset_metapaths("acm"), max_edges=20000)
    return g, target, ncls, labels, mp


def test_neighbor_aggregate_multi_fused_fp_matches_multigraph(acm):
    g, target, ncls, labels, mp = acm
    data = prepare_data(g, mp, target, ncls, labels, block=16)
    rng = np.random.default_rng(0)
    gn = len(data.graphs)
    x = data.features[target]
    din, heads, dh = x.shape[1], 2, 4
    w = jnp.asarray((rng.standard_normal((din, heads * dh)) / np.sqrt(din)
                     ).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((heads * dh,)).astype(np.float32))
    a_s = jnp.asarray(rng.standard_normal((gn, heads, dh)).astype(np.float32))
    a_d = jnp.asarray(rng.standard_normal((gn, heads, dh)).astype(np.float32))
    fp = FusedFPInputs.shared(x, w, b, a_s, a_d)
    z_f = neighbor_aggregate_multi(
        data.graphs, None, None, None, backend=NABackend.FUSED_FP_INTERPRET, fp=fp)
    h = (x @ w + b).reshape(x.shape[0], heads, dh)
    th_s = jnp.einsum("nhd,ghd->gnh", h, a_s)
    th_d = jnp.einsum("nhd,ghd->gnh", h, a_d)
    z_m = neighbor_aggregate_multi(
        data.graphs, th_s, th_d, h, backend=NABackend.MULTIGRAPH_INTERPRET)
    np.testing.assert_allclose(np.asarray(z_f), np.asarray(z_m), rtol=1e-4, atol=1e-6)


def test_neighbor_aggregate_multi_fused_fp_requires_fp(acm):
    g, target, ncls, labels, mp = acm
    data = prepare_data(g, mp, target, ncls, labels, block=16)
    with pytest.raises(ValueError, match="fp"):
        neighbor_aggregate_multi(
            data.graphs, None, None, None, backend=NABackend.FUSED_FP_INTERPRET)


@pytest.fixture(scope="module")
def dblp_fp():
    rng = np.random.default_rng(0)
    g = synthetic_hetgraph("dblp", scale=0.05, feat_scale=0.1)
    sgs = build_semantic_graphs(g, dataset_metapaths("dblp"))
    batches = [batch_semantic_graph(s, block=16) for s in sgs]
    G, ns = len(batches), batches[0].num_src
    ns_pad = max(((ns + 15) // 16) * 16, batches[0].num_dst_pad)
    din = 24
    x = np.zeros((ns_pad, din), np.float32)
    x[:ns] = rng.standard_normal((ns, din))
    w = (rng.standard_normal((din, H * DH)) / np.sqrt(din)).astype(np.float32)
    b = rng.standard_normal((H * DH,)).astype(np.float32) * 0.1
    a_s = rng.standard_normal((G, H, DH)).astype(np.float32)
    a_d = rng.standard_normal((G, H, DH)).astype(np.float32)
    fp = FusedFPInputs.shared(*map(jnp.asarray, (x, w, b, a_s, a_d)))
    h = (fp.x @ jnp.asarray(w) + jnp.asarray(b)).reshape(ns_pad, H, DH)
    ths = jnp.einsum("nhd,ghd->gnh", h, jnp.asarray(a_s))
    thd = jnp.einsum("nhd,ghd->gnh", h, jnp.asarray(a_d))
    return batches, fp, ths, thd, h


def test_multilane_fused_fp_matches_reference(dblp_fp):
    batches, fp, ths, thd, h = dblp_fp
    plan = build_multilane_plan(batches, 4)
    ref = multilane_na(plan, ths, thd, h)
    out = multilane_na(plan, None, None, None, backend="fused_fp_interpret", fp=fp)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-5)


def test_multilane_sharded_fused_fp_matches(dblp_fp):
    from repro.launch.mesh import make_lane_mesh

    batches, fp, ths, thd, h = dblp_fp
    plan = build_multilane_plan(batches, 4)
    ref = multilane_na(plan, ths, thd, h)
    mesh = make_lane_mesh(1, 1)
    out = multilane_na_sharded(
        plan, None, None, None, mesh=mesh, backend="fused_fp_interpret", fp=fp)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-5)


def test_han_fused_fp_backend_matches_and_trains(acm):
    """The megakernel is a drop-in HAN backend: one launch per layer, h'
    never materialized, grads agree with the multigraph path."""
    g, target, ncls, labels, mp = acm
    data = prepare_data(g, mp, target, ncls, labels, block=16)
    model = MODELS["HAN"]
    params = model.init(jax.random.key(2), data)
    l_mg = model.forward(params, data, backend=NABackend.MULTIGRAPH_INTERPRET)
    l_ff = model.forward(params, data, backend=NABackend.FUSED_FP_INTERPRET)
    np.testing.assert_allclose(np.asarray(l_ff), np.asarray(l_mg), rtol=5e-5, atol=5e-5)

    def loss(p, be):
        return cross_entropy(model.forward(p, data, backend=be), data.labels)

    g_ff = jax.grad(loss)(params, NABackend.FUSED_FP_INTERPRET)
    g_mg = jax.grad(loss)(params, NABackend.MULTIGRAPH_INTERPRET)
    for k in g_mg:
        np.testing.assert_allclose(
            np.asarray(g_ff[k]), np.asarray(g_mg[k]), rtol=1e-3, atol=1e-5, err_msg=k)


# -- serving: cache-aware dispatch ----------------------------------------


def test_engine_fused_fp_matches_multigraph_and_bypasses_on_cache_hit():
    from repro.serve import GraphRequest, HGNNEngine

    g = synthetic_hetgraph("acm", scale=0.1, feat_scale=0.1, seed=0)
    mps = [("paper", "author", "paper"), ("paper", "subject", "paper")]

    def run(backend, prewarm=False):
        eng = HGNNEngine(g, target_type="paper", backend=backend,
                         max_edges=6_000, seed=0)
        if prewarm:
            eng.cache.project("paper", eng.features["paper"],
                              eng.params["w_fp"]["paper"], eng.params["b_fp"]["paper"])
        for rid in range(3):
            eng.submit(GraphRequest(rid=rid, metapaths=list(mps)))
        eng.run()
        return eng

    em = run(NABackend.MULTIGRAPH_INTERPRET)
    ef = run(NABackend.FUSED_FP_INTERPRET)
    ew = run(NABackend.FUSED_FP_INTERPRET, prewarm=True)

    # cache miss: every step went through the megakernel, same numbers
    assert ef.fused_steps == ef.steps_run and ef.fused_cache_bypasses == 0
    # full-table cache hit: FP is sunk cost -> projected multigraph path
    assert ew.fused_steps == 0 and ew.fused_cache_bypasses == ew.steps_run
    assert ew.cache.table_coverage("paper", ew.n_target) == 1.0
    for a, b_, c in zip(em.finished, ef.finished, ew.finished):
        np.testing.assert_allclose(
            np.asarray(b_.result), np.asarray(a.result), rtol=1e-4, atol=1e-6)
        np.testing.assert_allclose(
            np.asarray(c.result), np.asarray(a.result), rtol=1e-4, atol=1e-6)
    m = ef.metrics()
    assert m["fused_steps"] == ef.fused_steps
    assert "fused_cache_bypasses" in m
