"""NA stage semantics: backend equivalence, softmax invariants, reuse."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import NABackend, batch_semantic_graph, count_reuse, fp_buffer_traffic, neighbor_aggregate
from repro.core import stages
from repro.graphs import build_semantic_graphs, dataset_metapaths, synthetic_hetgraph
from repro.graphs.hetgraph import SemanticGraph


def _random_sg(rng, n_src, n_dst, n_edges):
    src = rng.integers(0, n_src, n_edges).astype(np.int32)
    dst = rng.integers(0, n_dst, n_edges).astype(np.int32)
    key = src.astype(np.int64) * n_dst + dst
    _, idx = np.unique(key, return_index=True)
    return SemanticGraph(
        name="T", src_type="a", dst_type="b",
        src_ids=src[idx], dst_ids=dst[idx],
        num_src=n_src, num_dst=n_dst, path_types=("a", "b"),
    )


@settings(max_examples=20, deadline=None)
@given(st.data())
def test_segment_equals_block_online_softmax(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 9999)))
    n_src = data.draw(st.integers(4, 40))
    n_dst = data.draw(st.integers(4, 40))
    n_edges = data.draw(st.integers(1, 120))
    h = data.draw(st.integers(1, 3))
    dh = data.draw(st.sampled_from([4, 8]))
    sg = _random_sg(rng, n_src, n_dst, n_edges)
    batch = batch_semantic_graph(sg, block=8)
    ths = jnp.asarray(rng.standard_normal((n_src, h)).astype(np.float32))
    thd = jnp.asarray(rng.standard_normal((n_dst, h)).astype(np.float32))
    hs = jnp.asarray(rng.standard_normal((n_src, h, dh)).astype(np.float32))
    z_seg = neighbor_aggregate(batch, ths, thd, hs, backend=NABackend.SEGMENT)
    z_blk = neighbor_aggregate(batch, ths, thd, hs, backend=NABackend.BLOCK)
    np.testing.assert_allclose(np.asarray(z_seg), np.asarray(z_blk), rtol=3e-5, atol=3e-5)


def test_na_permutation_invariance():
    rng = np.random.default_rng(0)
    sg = _random_sg(rng, 30, 30, 90)
    ths = jnp.asarray(rng.standard_normal((30, 2)).astype(np.float32))
    thd = jnp.asarray(rng.standard_normal((30, 2)).astype(np.float32))
    hs = jnp.asarray(rng.standard_normal((30, 2, 8)).astype(np.float32))
    perm = rng.permutation(sg.num_edges)
    sg2 = SemanticGraph(
        name="T", src_type="a", dst_type="b",
        src_ids=sg.src_ids[perm], dst_ids=sg.dst_ids[perm],
        num_src=30, num_dst=30, path_types=("a", "b"),
    )
    b1 = batch_semantic_graph(sg, block=8)
    b2 = batch_semantic_graph(sg2, block=8)
    z1 = neighbor_aggregate(b1, ths, thd, hs, backend=NABackend.SEGMENT)
    z2 = neighbor_aggregate(b2, ths, thd, hs, backend=NABackend.SEGMENT)
    np.testing.assert_allclose(np.asarray(z1), np.asarray(z2), rtol=2e-5, atol=2e-5)


def test_attention_weights_are_convex_combination():
    """z_v must lie in the convex hull of neighbor features (weights sum 1)."""
    rng = np.random.default_rng(1)
    sg = _random_sg(rng, 20, 20, 60)
    ths = jnp.asarray(rng.standard_normal((20, 1)).astype(np.float32))
    thd = jnp.asarray(rng.standard_normal((20, 1)).astype(np.float32))
    hs = jnp.ones((20, 1, 4), jnp.float32)  # all-ones features
    batch = batch_semantic_graph(sg, block=8)
    z = neighbor_aggregate(batch, ths, thd, hs, backend=NABackend.SEGMENT)
    deg = np.bincount(sg.dst_ids, minlength=20)
    has = deg > 0
    np.testing.assert_allclose(np.asarray(z)[has], 1.0, rtol=1e-5)


def test_mean_aggregate_matches_numpy():
    rng = np.random.default_rng(2)
    sg = _random_sg(rng, 15, 12, 40)
    hs = rng.standard_normal((15, 6)).astype(np.float32)
    batch = batch_semantic_graph(sg, block=8)
    from repro.core import mean_aggregate

    z = np.asarray(mean_aggregate(batch, jnp.asarray(hs)))
    ref = np.zeros((12, 6), np.float32)
    cnt = np.bincount(sg.dst_ids, minlength=12)
    np.add.at(ref, sg.dst_ids, hs[sg.src_ids])
    ref = ref / np.maximum(cnt, 1)[:, None]
    np.testing.assert_allclose(z, ref, rtol=2e-5, atol=2e-5)


def test_reuse_counters_and_fp_traffic_ordering():
    g = synthetic_hetgraph("acm", scale=0.2, feat_scale=0.1)
    sgs = build_semantic_graphs(g, dataset_metapaths("acm"), max_edges=20000)
    c = count_reuse(sgs, g.vertex_counts)
    assert c.fp_dedup <= c.fp_naive
    assert c.theta_dedup == sum(s.num_src + s.num_dst for s in sgs)
    bpv = {t: g.feature_dim(t) * 4 for t in g.vertex_counts}
    small_buf = sum(g.vertex_counts[t] * bpv[t] for t in g.vertex_counts) // 3
    # similarity order should reuse at least as much as the worst order
    from repro.core import similarity_schedule

    order, _ = similarity_schedule(sgs, g.vertex_counts)
    t_sim = fp_buffer_traffic(order, sgs, g.vertex_counts, bytes_per_vertex=bpv, fpbuf_bytes=small_buf)
    worst = min(
        fp_buffer_traffic(p, sgs, g.vertex_counts, bytes_per_vertex=bpv, fpbuf_bytes=small_buf).reuse_fraction
        for p in ([0, 2, 1, 3], [3, 1, 0, 2], [1, 3, 0, 2])
    )
    assert t_sim.reuse_fraction >= worst - 1e-9


def test_local_global_semantic_fusion():
    rng = np.random.default_rng(3)
    z = jnp.asarray(rng.standard_normal((3, 10, 8)).astype(np.float32))
    wg = jnp.asarray(rng.standard_normal((8, 4)).astype(np.float32))
    bg = jnp.zeros((4,))
    q = jnp.asarray(rng.standard_normal((4,)).astype(np.float32))
    valid = jnp.ones((10,), bool)
    w_p = jnp.stack([stages.local_semantic_fusion(z[p], wg, bg, q, valid) for p in range(3)])
    fused, beta = stages.global_semantic_fusion(w_p, z)
    assert fused.shape == (10, 8)
    np.testing.assert_allclose(float(beta.sum()), 1.0, rtol=1e-6)
    # GSF is a convex combination across graphs
    mn = np.asarray(z).min(0) - 1e-6
    mx = np.asarray(z).max(0) + 1e-6
    assert ((np.asarray(fused) >= mn) & (np.asarray(fused) <= mx)).all()
