"""Decode == teacher-forced forward, per architecture family.

The strongest correctness property the serving engine has: stepping one
token at a time through the caches must reproduce the full-sequence
forward logits exactly (same params, same inputs)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models.lm import transformer as tfm
from repro.models.lm.api import build


@pytest.mark.parametrize("arch", ["qwen2-7b", "dbrx-132b", "recurrentgemma-9b", "mamba2-2.7b"])
def test_decode_matches_forward(arch):
    cfg = smoke_config(arch)
    if cfg.is_moe:
        # capacity-based MoE only matches decode when nothing drops in the
        # full-sequence pass (decode routes each token alone — no slot
        # competition); ample capacity makes both paths dropless
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    api = build(cfg)
    params = api.init(jax.random.key(0))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    ref, _ = api.forward(params, toks)

    caches = tfm.init_caches(cfg, B, S, jnp.float32)
    outs = []
    for t in range(S):
        lg, caches = tfm.decode_step(params, cfg, toks[:, t : t + 1], jnp.int32(t), caches)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32), np.asarray(ref, np.float32), rtol=5e-4, atol=5e-4
    )


def test_moe_capacity_overflow_drops_to_residual():
    """Tokens beyond expert capacity are dropped (the paper's OW analogue):
    with capacity_factor ~0 every token is dropped and the MoE output is 0."""
    from repro.models.lm.layers import init_from_specs
    from repro.models.lm.moe import moe_forward, moe_specs

    cfg = dataclasses.replace(
        smoke_config("dbrx-132b"), moe_capacity_factor=1e-6
    )
    params = init_from_specs(moe_specs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model), jnp.float32)
    out, aux = moe_forward(params, x, cfg)
    # capacity floor is 8 slots; with S*k=32 copies, at most 8 per expert
    # survive — but with cf≈0 the capacity floor still admits a few; the
    # key invariant is boundedness + finiteness, and that a *large*
    # capacity admits strictly more mass
    cfg_big = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    out_big, _ = moe_forward(params, x, cfg_big)
    assert np.isfinite(np.asarray(out)).all()
    assert float(jnp.abs(out_big).sum()) >= float(jnp.abs(out).sum()) - 1e-5


def test_moe_gates_are_renormalized_topk():
    from repro.models.lm.layers import init_from_specs
    from repro.models.lm.moe import moe_forward, moe_specs

    cfg = smoke_config("grok-1-314b")
    params = init_from_specs(moe_specs(cfg), jax.random.key(2))
    x = jnp.ones((1, 8, cfg.d_model), jnp.float32) * 0.1
    out, aux = moe_forward(params, x, cfg)
    assert out.shape == x.shape
    assert float(aux) >= 1.0 - 1e-5  # switch aux loss lower bound at uniform routing
